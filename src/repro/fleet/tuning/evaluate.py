"""Vectorized candidate evaluation: configs x seeded trace replicates.

The scenario pre-samples ONE Monte Carlo workload tensor (n_seeds trace
replicates) and every candidate config is simulated against slices of that
same tensor. Candidates are therefore *paired* on identical arrival draws:
the difference between two candidates' per-seed scores is free of the
arrival-sampling variance a naive sweep (fresh traces per candidate) pays —
the classic common-random-numbers variance reduction, and what lets the
racing loop compare candidates on very few replicates.

Per candidate the evaluator returns per-seed dollar cost, worst-class SLO
attainment and drop rate (the simulator is already seed-vectorized, so one
``simulate_fleet`` call covers a whole seed slice), the pooled per-request
p99, and across-seed confidence intervals.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Callable, Optional

import numpy as np

from repro.core.cost_model import dollar_cost
from repro.fleet import telemetry
from repro.fleet.report import weighted_percentile
from repro.fleet.simulator import (FleetConfig, SimResult,
                                   draw_cold_start_delays, simulate_fleet)
from repro.fleet.traces import Trace
from repro.fleet.workload import Workload

_Z95 = 1.959963984540054


@dataclass(frozen=True)
class Objective:
    """Scalarization of (cost, SLO attainment): dollars per hour plus a steep
    penalty per unit of worst-class attainment shortfall below the bar. The
    penalty converts "meet the SLO" into a soft constraint the tuner can
    race on — a config missing the bar by 1% pays ``penalty_usd_per_hour/100``
    extra $/hr, dwarfing any honest capacity saving."""
    min_attainment: float = 0.99
    penalty_usd_per_hour: float = 2000.0

    def score(self, cost_usd_hr, attainment):
        """Per-seed scalar score (lower is better); inputs broadcast."""
        shortfall = np.maximum(self.min_attainment - np.asarray(attainment),
                               0.0)
        return np.asarray(cost_usd_hr) + self.penalty_usd_per_hour * shortfall

    def to_json(self) -> dict:
        return {"min_attainment": self.min_attainment,
                "penalty_usd_per_hour": self.penalty_usd_per_hour}

    @staticmethod
    def from_json(d: dict) -> "Objective":
        return Objective(min_attainment=float(d["min_attainment"]),
                         penalty_usd_per_hour=float(d["penalty_usd_per_hour"]))


@dataclass
class CandidateEval:
    """One candidate's evidence so far (arrays grow as racing adds seeds)."""
    params: dict
    cost_usd_hr: np.ndarray          # (n_seeds_seen,)
    attainment: np.ndarray           # (n_seeds_seen,) worst-class
    drop_rate: np.ndarray            # (n_seeds_seen,)
    score: np.ndarray                # (n_seeds_seen,) objective scalarization
    sojourns: list = field(repr=False, default_factory=list)  # (vals, wts)
    n_rounds: int = 0                # racing rounds survived

    @property
    def n_seeds(self) -> int:
        return len(self.score)

    def mean_cost(self) -> float:
        return float(self.cost_usd_hr.mean())

    def mean_attainment(self) -> float:
        return float(self.attainment.mean())

    def mean_drop_rate(self) -> float:
        return float(self.drop_rate.mean())

    def mean_score(self) -> float:
        return float(self.score.mean())

    def ci(self, arr: np.ndarray) -> float:
        """95% half-width of the mean (0 with a single replicate)."""
        if len(arr) < 2:
            return 0.0
        return float(_Z95 * arr.std(ddof=1) / np.sqrt(len(arr)))

    def cost_ci(self) -> float:
        return self.ci(self.cost_usd_hr)

    def attainment_ci(self) -> float:
        return self.ci(self.attainment)

    def score_ci(self) -> float:
        return self.ci(self.score)

    def p99_s(self) -> float:
        """Pooled exact per-request p99 over every seed seen."""
        if not self.sojourns:
            return float("nan")
        vals = np.concatenate([v for v, _ in self.sojourns])
        wts = np.concatenate([w for _, w in self.sojourns])
        return weighted_percentile(vals, wts, 99)

    def extend(self, other: "CandidateEval") -> None:
        """Append another seed slice's evidence (paired racing rounds)."""
        self.cost_usd_hr = np.concatenate([self.cost_usd_hr,
                                           other.cost_usd_hr])
        self.attainment = np.concatenate([self.attainment, other.attainment])
        self.drop_rate = np.concatenate([self.drop_rate, other.drop_rate])
        self.score = np.concatenate([self.score, other.score])
        self.sojourns.extend(other.sojourns)

    def to_json(self, include_sojourns: bool = False) -> dict:
        """Plain-JSON form of this candidate's evidence. Per-request sojourn
        samples are dropped by default (they dominate the payload and only
        feed ``p99_s``); pass ``include_sojourns=True`` to keep them."""
        out = {"params": dict(self.params),
               "cost_usd_hr": [float(v) for v in self.cost_usd_hr],
               "attainment": [float(v) for v in self.attainment],
               "drop_rate": [float(v) for v in self.drop_rate],
               "score": [float(v) for v in self.score],
               "n_rounds": int(self.n_rounds)}
        if include_sojourns:
            out["sojourns"] = [([float(x) for x in v], [float(x) for x in w])
                               for v, w in self.sojourns]
        return out

    @staticmethod
    def from_json(d: dict) -> "CandidateEval":
        sojourns = [(np.asarray(v, float), np.asarray(w, float))
                    for v, w in d.get("sojourns", [])]
        return CandidateEval(
            params=dict(d["params"]),
            cost_usd_hr=np.asarray(d["cost_usd_hr"], float),
            attainment=np.asarray(d["attainment"], float),
            drop_rate=np.asarray(d["drop_rate"], float),
            score=np.asarray(d["score"], float),
            sojourns=sojourns, n_rounds=int(d.get("n_rounds", 0)))


def _slice_trace(tr: Trace, s0: int, s1: int) -> Trace:
    return Trace(tr.name, tr.dt_s, tr.rate, tr.arrivals[s0:s1])


def _slice_workload(wl: Workload, s0: int, s1: int) -> Workload:
    return Workload(wl.name, wl.classes,
                    tuple(_slice_trace(tr, s0, s1) for tr in wl.traces))


@dataclass
class TuningScenario:
    """Everything ``tune()`` needs to score a candidate config:

    * ``workload``  — the shared Monte Carlo trace tensor (a ``Workload``, or
      a bare ``Trace`` + ``slo_s``); its seed axis is the replicate budget.
    * ``fleet``     — the fleet template (``quota:<pool>`` dims override each
      pool's ``max_replicas`` per candidate).
    * ``policy_cls`` + ``context`` — the policy family under tuning;
      candidates are built with ``policy_cls.from_params(params, **context)``.
    * ``discipline``/``max_queue``/``cold_start_seed`` — simulation fixtures
      (a ``discipline`` dim in the space overrides the fixture).
    * ``backend`` — the simulator implementation candidates are scored on:
      ``"numpy"`` (reference), ``"jax"`` (compiled; a whole racing round is
      one jitted candidate x seed batch), or ``"auto"`` (the default:
      compiled when the policy family has a kernel, numpy otherwise — every
      built-in family has one, and both paths agree to float rounding).
    * ``n_substeps``/``preemptive`` — simulator fidelity knobs forwarded to
      every ``simulate_fleet`` call (see the simulator docstring); the
      defaults keep the coarse bin-granular core.
    """
    name: str
    workload: Workload
    fleet: FleetConfig
    policy_cls: type
    context: dict = field(default_factory=dict)
    discipline: str = "fifo"
    max_queue: Optional[float] = None
    cold_start_seed: int = 0
    build_policy: Callable = None    # override: params -> Policy
    backend: str = "auto"
    n_substeps: int = 1
    preemptive: bool = False

    def __post_init__(self):
        if isinstance(self.workload, Trace):
            slo = self.context.get("slo_s")
            if slo is None:
                raise ValueError("a bare Trace workload needs context"
                                 "['slo_s'] for its request class")
            self.workload = Workload.from_trace(self.workload, float(slo))
        self._cs_delay = False       # lazy cold-start jitter tensor cache
        self._tables = {}            # per-discipline cohort_tables cache
        self._batch_windows = None   # sticky kernel ring-buffer sizes

    @property
    def n_seeds(self) -> int:
        return self.workload.n_seeds

    def cold_start_delays(self):
        """The (n_seeds, n_bins, n_pools) spin-up jitter tensor, drawn ONCE
        per scenario and sliced per racing round — every candidate sees
        identical draws anyway (they are keyed by absolute seed identity),
        so re-drawing them per ``simulate_fleet`` call was pure per-candidate
        RNG overhead. ``None`` when no pool jitters."""
        if self._cs_delay is False:
            self._cs_delay = draw_cold_start_delays(
                self.fleet.pools, self.n_seeds, self.workload.n_bins,
                self.workload.dt_s, self.cold_start_seed,
                np.arange(self.n_seeds))
        return self._cs_delay

    def _cs_rows(self, s0: int, s1: int):
        cs = self.cold_start_delays()
        return None if cs is None else cs[s0:s1]

    def cohort_tables_for(self, discipline):
        """Cached static serve-order tables for the compiled backend."""
        from repro.fleet.discipline import cohort_tables
        key = discipline if isinstance(discipline, str) else id(discipline)
        tabs = self._tables.get(key)
        if tabs is None:
            tabs = cohort_tables(discipline, self.workload.classes,
                                 self.workload.n_bins, self.workload.dt_s)
            self._tables[key] = tabs
        return tabs

    def split_params(self, params: dict):
        """(policy_params, discipline, fleet) for one candidate — the
        cross-cutting ``discipline``/``quota:*`` dims are simulation-level,
        everything else belongs to the policy constructor."""
        policy_params = {k: v for k, v in params.items()
                         if k != "discipline" and not k.startswith("quota:")}
        discipline = params.get("discipline", self.discipline)
        fleet = self.fleet
        quotas = {k[len("quota:"):]: int(v) for k, v in params.items()
                  if k.startswith("quota:")}
        if quotas:
            pools = tuple(
                replace(p, max_replicas=quotas[p.label],
                        min_replicas=min(p.min_replicas, quotas[p.label]))
                if p.label in quotas else p for p in fleet.pools)
            fleet = FleetConfig(pools, max_queue=fleet.max_queue)
        return policy_params, discipline, fleet

    def make_policy(self, params: dict):
        policy_params, _, fleet = self.split_params(params)
        if self.build_policy is not None:
            return self.build_policy(policy_params)
        ctx = dict(self.context)
        ctx.pop("slo_s", None)
        if "fleet" in ctx or getattr(self.policy_cls, "per_pool", False):
            ctx["fleet"] = fleet
        return self.policy_cls.from_params(policy_params, **ctx)

    def simulate(self, params: dict, s0: int, s1: int,
                 backend: str = None) -> SimResult:
        """Run one candidate against the shared seed slice [s0, s1).
        ``seed_indices`` pins each row's cold-start jitter substream to its
        absolute replicate id, so racing's incremental slices see exactly
        the draws a single full-budget evaluation would (the scenario hands
        the pre-drawn tensor rows straight to the simulator)."""
        _, discipline, fleet = self.split_params(params)
        return simulate_fleet(
            _slice_workload(self.workload, s0, s1), fleet,
            self.make_policy(params), discipline=discipline,
            max_queue=self.max_queue, cold_start_seed=self.cold_start_seed,
            seed_indices=np.arange(s0, s1),
            cold_start_delays=self._cs_rows(s0, s1),
            backend=self.backend if backend is None else backend,
            n_substeps=self.n_substeps, preemptive=self.preemptive)


def per_seed_metrics(sim: SimResult):
    """(cost $/hr, worst-class attainment, drop rate), each (n_seeds,), from
    one seed-vectorized simulation — the per-seed analogues of
    ``report.summarize``'s scalars (same conventions: drops count against
    attainment, the unresolved terminal backlog counts for neither side)."""
    S = sim.arrivals.shape[0]
    usd = np.zeros(S)
    for p, pc in enumerate(sim.fleet.pools):
        bins = sim.pool_billed[:, :, p].sum(axis=1)
        usd += dollar_cost(sim.dt_s, bins, pc.service.shape.chips,
                           pc.service.shape.hw)
    cost_hr = usd / max(sim.trace.duration_s / 3600.0, 1e-12)

    arrived_c = (sim.class_admitted + sim.class_dropped).sum(axis=1)
    completed_c = arrived_c - sim.class_queue[:, -1, :]
    ok_c = sim.class_ok.sum(axis=1)
    att_c = np.divide(ok_c, completed_c, out=np.ones_like(ok_c),
                      where=completed_c > 0)
    worst_att = att_c.min(axis=1)

    arrived = sim.arrivals.sum(axis=1)
    drop = sim.dropped.sum(axis=1) / np.maximum(arrived, 1.0)
    return cost_hr, worst_att, drop


def _eval_from_sim(params: dict, sim: SimResult,
                   objective: Objective) -> CandidateEval:
    cost_hr, att, drop = per_seed_metrics(sim)
    return CandidateEval(
        params=dict(params), cost_usd_hr=cost_hr, attainment=att,
        drop_rate=drop, score=np.asarray(objective.score(cost_hr, att)),
        sojourns=[(sim.sojourn_values, sim.sojourn_weights)])


def _evaluate_batched(scenario: TuningScenario, candidates: list,
                      objective: Objective, s0: int, s1: int):
    """Score the whole candidate slate in ONE jitted dispatch: stack every
    candidate's kernel params, discipline tables and quota bounds, run the
    compiled candidate x seed lattice, then finish each candidate's exact
    latency accounting on the host. Returns ``None`` when the slate cannot
    batch (no jax, custom ``build_policy``, a family without a kernel)."""
    from repro.fleet import jaxsim
    if not jaxsim.available() or scenario.build_policy is not None:
        return None
    from repro.fleet.discipline import get_discipline
    from repro.fleet.simulator import (_candidate_arrays, _dynamics_inputs,
                                       _result_from_dynamics)

    wl = _slice_workload(scenario.workload, s0, s1)
    policies, discs, fleets = [], [], []
    for params in candidates:
        _, disc, fleet = scenario.split_params(params)
        policies.append(scenario.make_policy(params))
        discs.append(disc)
        fleets.append(fleet)
    # same contract as simulate_fleet: a single-target policy cannot drive a
    # multi-pool fleet (broadcasting its target across pools would score a
    # semantically meaningless config instead of failing)
    P = fleets[0].n_pools
    if P > 1 and not getattr(policies[0], "per_pool", False):
        raise ValueError(f"policy {policies[0].name!r} returns a single "
                         f"target; a {P}-pool fleet needs a per-pool policy "
                         "(e.g. HeterogeneousPredictivePolicy)")

    # ring-buffer sizes must be static across the batch AND sticky across
    # racing rounds (a shrinking round must reuse the compiled program)
    windows = [int(p.forecaster.window_bins) for p in policies
               if hasattr(p, "forecaster")]
    # fit-to-usage keeps its own ring buffer (window_bins, no forecaster)
    windows += [int(p.window_bins) for p in policies
                if not hasattr(p, "forecaster") and hasattr(p, "window_bins")]
    sustains = [int(p.sustain.window_bins) for p in policies
                if hasattr(p, "sustain")]
    prev = scenario._batch_windows or (0, 0)
    W = max([prev[0]] + windows) or None
    Ws = max([prev[1]] + sustains) or None
    scenario._batch_windows = (W or 0, Ws or 0)

    template = fleets[0]
    if not hasattr(policies[0], "kernel"):
        return None
    kernel = policies[0].kernel(template, wl.classes,
                                max_window=W, max_sustain=Ws)
    if kernel is None:
        return None
    kp_rows = []
    for pol, fleet in zip(policies, fleets):
        k = pol.kernel(fleet, wl.classes, max_window=W, max_sustain=Ws)
        if k is not kernel:         # mixed families/configs cannot batch
            return None
        kp_rows.append(kernel.params_of(pol))

    order = template.drain_order()
    tables = [scenario.cohort_tables_for(d) for d in discs]
    rate0 = wl.total_trace().rate[0]
    bounds = [_candidate_arrays(f, order, rate0) for f in fleets]
    max_queue = (template.max_queue if scenario.max_queue is None
                 else scenario.max_queue)
    out = jaxsim.run_dynamics(
        kernel, **_dynamics_inputs(wl, template, order,
                                   scenario._cs_rows(s0, s1)),
        max_queue=max_queue,
        tables={k: np.stack([t[k] for t in tables])
                for k in ("cnt", "cls_of_rank", "drop_rank", "key_of_rank")},
        kp={k: np.array([r[k] for r in kp_rows])
            for k in kernel.param_names},
        min_rep=np.stack([b[0] for b in bounds]),
        max_rep=np.stack([b[1] for b in bounds]),
        init_ready=np.stack([b[2] for b in bounds]),
        n_substeps=scenario.n_substeps, preemptive=scenario.preemptive)
    slos = wl.slos()
    evals = []
    for i, params in enumerate(candidates):
        sim = _result_from_dynamics(
            wl, fleets[i], get_discipline(discs[i]), policies[i].name,
            order, slos, {k: v[i] for k, v in out.items()},
            n_substeps=scenario.n_substeps, preemptive=scenario.preemptive)
        evals.append(_eval_from_sim(params, sim, objective))
    return evals


def evaluate_candidates(scenario: TuningScenario, candidates: list,
                        objective: Objective, s0: int = 0,
                        s1: int = None, backend: str = None) -> list:
    """Score every candidate on the shared seed slice [s0, s1); identical
    slices across candidates give the paired comparison racing relies on.

    On the numpy backend, one seed-vectorized ``simulate_fleet`` call per
    candidate covers the whole slice. On the jax backend the entire
    candidate slate is scored in one jitted candidate x seed dispatch
    (``_evaluate_batched``); ``"auto"`` batches when the policy family has a
    compiled kernel and falls back to the numpy loop otherwise. ``backend``
    overrides the scenario's own setting."""
    s1 = scenario.n_seeds if s1 is None else s1
    if not 0 <= s0 < s1 <= scenario.n_seeds:
        raise ValueError(f"bad seed slice [{s0}, {s1}) for "
                         f"{scenario.n_seeds} replicates")
    if not candidates:
        return []
    backend = scenario.backend if backend is None else backend
    if backend not in ("numpy", "jax", "auto"):
        raise ValueError(f"unknown backend {backend!r}; "
                         "expected 'numpy', 'jax' or 'auto'")
    telemetry.counter("tuning_sims_total",
                      len(candidates) * (s1 - s0), backend=backend)
    if backend != "numpy":
        evals = _evaluate_batched(scenario, candidates, objective, s0, s1)
        if evals is not None:
            return evals
        if backend == "jax":
            from repro.fleet import jaxsim
            if not jaxsim.available():
                raise ValueError("backend='jax' requires jax to be installed "
                                 "(use backend='auto' to fall back to numpy)")
            raise ValueError(
                "backend='jax': this scenario cannot batch (custom "
                "build_policy or a policy family without a compiled "
                "kernel); use backend='auto' to fall back to numpy")
    out = []
    for params in candidates:
        sim = scenario.simulate(params, s0, s1, backend="numpy")
        out.append(_eval_from_sim(params, sim, objective))
    return out
