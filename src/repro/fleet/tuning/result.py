"""Tuning outputs: the Pareto frontier and the ``TuningReport``.

The report is the controller-scoping analogue of the paper's per-use-case
deliverable: the recommended (winner) configuration, the cost-vs-attainment
frontier a deployer can trade along, the fitted response surface over the
controller knobs (Figs. 4-8 methodology with autoscaler parameters as the
design variables, rendered as the same ASCII contour), and the simulation
budget the racing loop actually spent getting there.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

import numpy as np

from repro.core.report import fmt_time, markdown_table
from repro.core.surfaces import ResponseSurface, render_ascii_surface

_ATT_EPS = 1e-9


def pareto_frontier(evals: list) -> tuple:
    """Non-dominated (mean cost, mean worst-class attainment) subset of
    ``evals``, sorted cheapest-first with strictly increasing attainment —
    every member is the cheapest way to buy at least its attainment."""
    pts = sorted(evals, key=lambda e: (e.mean_cost(), -e.mean_attainment()))
    out, best_att = [], -np.inf
    for e in pts:
        if e.mean_attainment() > best_att + _ATT_EPS:
            out.append(e)
            best_att = e.mean_attainment()
    return tuple(out)


def _fmt_param(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def frontier_table(frontier) -> str:
    rows = [[", ".join(f"{k}={_fmt_param(v)}"
                       for k, v in sorted(e.params.items())),
             f"${e.mean_cost():.2f}/hr ± {e.cost_ci():.2f}",
             f"{e.mean_attainment() * 100:.2f}% ± "
             f"{e.attainment_ci() * 100:.2f}",
             fmt_time(e.p99_s()),
             f"{e.mean_drop_rate() * 100:.2f}%",
             str(e.n_seeds)]
            for e in frontier]
    return markdown_table(
        ["config", "cost", "worst-class SLO", "p99", "drop", "seeds"], rows)


@dataclass
class TuningReport:
    """What ``tune()`` hands back: the winner and how much to trust it."""
    scenario_name: str
    policy_family: str
    objective: object                # evaluate.Objective
    winner: object                   # CandidateEval at full replicate budget
    frontier: tuple                  # Pareto CandidateEvals, cheapest first
    surface: Optional[ResponseSurface]
    surface_names: tuple = ()
    sims_used: int = 0
    full_budget: int = 0
    baseline: object = None          # CandidateEval of the hand-set config
    evals: list = field(default_factory=list, repr=False)
    space: object = None
    spans: object = None             # telemetry Span tree (None when off)
    robust: Optional[str] = None     # portfolio reduction ("worst_case", ...)
    n_traces: int = 1                # portfolio size candidates were scored on
    _scenario: object = field(default=None, repr=False)

    @property
    def budget_frac(self) -> float:
        return self.sims_used / max(self.full_budget, 1)

    @property
    def surface_r2(self) -> float:
        return float(self.surface.r2) if self.surface is not None else float("nan")

    def build_policy(self):
        """Instantiate the tuned policy (ready for ``simulate_fleet``)."""
        return self._scenario.make_policy(self.winner.params)

    def dominates_baseline(self) -> bool:
        """Tuned >= baseline attainment AND <= baseline cost, at least one
        strict (on the paired replicate means). False without a baseline."""
        if self.baseline is None:
            return False
        att_t, att_b = self.winner.mean_attainment(), \
            self.baseline.mean_attainment()
        c_t, c_b = self.winner.mean_cost(), self.baseline.mean_cost()
        return (att_t >= att_b - _ATT_EPS and c_t <= c_b + 1e-9
                and (att_t > att_b + _ATT_EPS or c_t < c_b - 1e-9))

    def ascii_surface(self, n_x: int = 16, n_y: int = 10) -> str:
        """ASCII contour of the fitted objective surface over the two leading
        numeric dims (others pinned at the winner), via the same renderer the
        scoping reports use. Empty string when no surface was fitted."""
        if self.surface is None or len(self.surface_names) < 2 \
                or self.space is None:
            return ""
        dims = {d.name: d for d in self.space.dims}
        dx, dy = (dims[n] for n in self.surface_names[:2])
        xs = np.array(dx.grid(n_x), float)
        ys = np.array(dy.grid(n_y), float)
        base = {n: float(self.winner.params[n]) for n in self.surface_names}
        Z = np.empty((len(ys), len(xs)))
        for i, y in enumerate(ys):
            for j, x in enumerate(xs):
                Z[i, j] = self.surface.predict(
                    dict(base, **{dx.name: float(x), dy.name: float(y)}))
        return render_ascii_surface(
            xs, ys, Z, dx.name, dy.name,
            title=f"objective surface (r2={self.surface.r2:.3f}), "
                  f"other dims at winner")

    def summary(self) -> str:
        lines = [f"# tuned {self.policy_family} on {self.scenario_name}",
                 "",
                 "winner: " + ", ".join(
                     f"{k}={_fmt_param(v)}"
                     for k, v in sorted(self.winner.params.items())),
                 f"  ${self.winner.mean_cost():.2f}/hr, worst-class SLO "
                 f"{self.winner.mean_attainment() * 100:.2f}%, p99 "
                 f"{fmt_time(self.winner.p99_s())} "
                 f"({self.winner.n_seeds} replicates)"]
        if self.baseline is not None:
            verdict = ("dominates" if self.dominates_baseline()
                       else "does not dominate")
            lines += [f"default: ${self.baseline.mean_cost():.2f}/hr, "
                      f"worst-class SLO "
                      f"{self.baseline.mean_attainment() * 100:.2f}% "
                      f"— tuned {verdict} the hand-set default"]
        if self.n_traces > 1:
            lines += [f"portfolio: {self.n_traces} traces reduced by "
                      f"{self.robust or 'worst_case'}; winner's worst-trace "
                      f"score ${self.winner.worst_trace_score():.2f}, "
                      f"worst-trace attainment "
                      f"{self.winner.worst_trace_attainment() * 100:.2f}%"]
        lines += ["", f"simulation budget: {self.sims_used} of "
                  f"{self.full_budget} candidate-seed-trace sims "
                  f"({self.budget_frac * 100:.0f}% of the naive sweep)"]
        if self.surface is not None:
            lines += [f"response surface over "
                      f"({', '.join(self.surface_names)}): "
                      f"r2 = {self.surface.r2:.3f}"]
        lines += ["", "cost-vs-attainment Pareto frontier:",
                  frontier_table(self.frontier)]
        timing = self.timing_breakdown()
        if timing:
            lines += ["", "timing breakdown (telemetry spans):", timing]
        art = self.ascii_surface()
        if art:
            lines += ["", art]
        return "\n".join(lines)

    def timing_breakdown(self) -> str:
        """Rendered span tree of this tune (sample -> racing rounds ->
        culls -> refine, with the compiled backend's cold/warm dispatches
        nested where they ran). Empty string when telemetry was off."""
        if self.spans is None:
            return ""
        from repro.fleet.telemetry import render_spans
        return render_spans([self.spans])

    # ---- serialization -----------------------------------------------------

    FORMAT = "tuning-report"
    VERSION = 1

    def to_json(self, *, include_evals: bool = True,
                include_spans: bool = False,
                include_sojourns: bool = False) -> dict:
        """Plain-JSON form of the report: winner, frontier, the surviving
        region (``evals`` with their racing-round counts — what
        ``warm_start_candidates`` and the oracle builder consume), surface,
        objective and budget. ``_scenario`` is a live object and is never
        serialized: a loaded report can seed a warm re-tune or an oracle
        cell but cannot ``build_policy()`` (re-attach a scenario for that).
        """
        d = {
            "format": self.FORMAT,
            "version": self.VERSION,
            "scenario_name": self.scenario_name,
            "policy_family": self.policy_family,
            "objective": self.objective.to_json(),
            "winner": self.winner.to_json(include_sojourns=include_sojourns),
            "frontier": [e.to_json(include_sojourns=include_sojourns)
                         for e in self.frontier],
            "baseline": (None if self.baseline is None else
                         self.baseline.to_json(
                             include_sojourns=include_sojourns)),
            "surface": (None if self.surface is None
                        else self.surface.to_json()),
            "surface_names": list(self.surface_names),
            "sims_used": int(self.sims_used),
            "full_budget": int(self.full_budget),
            "robust": self.robust,
            "n_traces": int(self.n_traces),
            "space": None if self.space is None else self.space.to_json(),
        }
        if include_evals:
            d["evals"] = [e.to_json(include_sojourns=include_sojourns)
                          for e in self.evals]
        if include_spans and self.spans is not None:
            d["spans"] = _span_to_json(self.spans)
        return d

    @staticmethod
    def from_json(d: dict) -> "TuningReport":
        from repro.fleet.tuning.evaluate import CandidateEval, Objective
        from repro.fleet.tuning.space import ParamSpace

        if d.get("format") != TuningReport.FORMAT:
            raise ValueError(f"not a tuning report (format="
                             f"{d.get('format')!r})")
        if int(d.get("version", -1)) > TuningReport.VERSION:
            raise ValueError(f"tuning report version {d.get('version')} is "
                             f"newer than this reader "
                             f"(<= {TuningReport.VERSION})")
        surface = (None if d.get("surface") is None
                   else ResponseSurface.from_json(d["surface"]))
        return TuningReport(
            scenario_name=d["scenario_name"],
            policy_family=d["policy_family"],
            objective=Objective.from_json(d["objective"]),
            winner=CandidateEval.from_json(d["winner"]),
            frontier=tuple(CandidateEval.from_json(e)
                           for e in d.get("frontier", [])),
            surface=surface,
            surface_names=tuple(d.get("surface_names", ())),
            sims_used=int(d.get("sims_used", 0)),
            full_budget=int(d.get("full_budget", 0)),
            baseline=(None if d.get("baseline") is None
                      else CandidateEval.from_json(d["baseline"])),
            evals=[CandidateEval.from_json(e) for e in d.get("evals", [])],
            space=(None if d.get("space") is None
                   else ParamSpace.from_json(d["space"])),
            robust=d.get("robust"),
            n_traces=int(d.get("n_traces", 1)),
            spans=(None if d.get("spans") is None
                   else _span_from_json(d["spans"])))


def _span_to_json(span) -> dict:
    return {"name": span.name, "attrs": dict(span.attrs),
            "duration_s": span.duration_s,
            "children": [_span_to_json(c) for c in span.children]}


def _span_from_json(d: dict):
    from repro.fleet.telemetry.spans import Span
    return Span(name=d["name"], attrs=dict(d.get("attrs", {})),
                duration_s=d.get("duration_s"),
                children=[_span_from_json(c) for c in d.get("children", [])])
