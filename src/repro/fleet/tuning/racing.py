"""Successive-halving candidate racing with SPRT-flavored early culling.

The naive sweep spends ``n_candidates x n_seeds`` simulations; most of that
budget goes to configs that are obviously dominated after two replicates.
Racing spends the budget where the decision is actually close:

* rounds double the replicate count (seed slices are *shared* across
  candidates, so per-seed score differences vs the incumbent are paired —
  ``evaluate.py``'s common-random-numbers setup; on the jax backend each
  round's surviving slate is scored as ONE compiled candidate x seed batch,
  with ``sims_used`` accounting unchanged);
* a candidate is culled early when the sequential log-likelihood ratio of its
  paired score deficit vs the incumbent crosses the Wald threshold
  ``ln((1-beta)/alpha)`` — the same two-hypothesis sequential test
  ``mset/sprt.py`` runs on MSET residuals, here on "is this config worse than
  the incumbent by at least one per-seed noise sigma?";
* independently of the SPRT, each round keeps at most ``ceil(n / eta)``
  survivors (classic successive halving), which bounds total spend at a small
  multiple of ``n_candidates x init_seeds`` regardless of how noisy the
  scenario is.

The full-budget reference (``exhaustive``) exists for benchmarking the
racer: on the seeded scenarios the tests pin, racing returns the same winner
for <= 40% of the exhaustive simulation budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.fleet import telemetry
from repro.fleet.tuning.evaluate import (CandidateEval, Objective,
                                         TuningScenario, evaluate_candidates)

_EPS = 1e-12


@dataclass
class RaceResult:
    evals: list                      # CandidateEval per candidate (all)
    winner: CandidateEval
    survivors: list                  # full-budget finalists (CandidateEval)
    sims_used: int                   # candidate x seed simulations spent
    full_budget: int                 # n_candidates x n_seeds (the naive sweep)
    culled_at_round: dict = field(default_factory=dict)  # cand idx -> round

    @property
    def budget_frac(self) -> float:
        return self.sims_used / max(self.full_budget, 1)


def _sprt_cull(deficits: np.ndarray, alpha: float, beta: float) -> bool:
    """Wald SPRT on paired per-seed score deficits d_i = cand_i - incumbent_i.

    H0: the candidate matches the incumbent (mean deficit 0); H1: it is worse
    by one per-seed noise sigma. With the effect size theta = sigma the
    log-likelihood ratio after n paired replicates reduces to
    ``sum(d)/sigma - n/2``; cull when it crosses ``ln((1-beta)/alpha)``.
    Degenerate spread (paired deficits all but equal) short-circuits on the
    sign: deterministically worse is culled, deterministically tied kept.
    """
    d = np.asarray(deficits, float)
    n = len(d)
    if n < 2:
        return False
    sigma = float(d.std(ddof=1))
    if sigma < _EPS:
        return bool(d.mean() > _EPS)
    llr = float(d.sum()) / sigma - n / 2.0
    return llr >= np.log((1.0 - beta) / alpha)


def race(scenario: TuningScenario, candidates: list, objective: Objective,
         *, init_seeds: int = 2, eta: int = 2, alpha: float = 0.05,
         beta: float = 0.05, min_survivors: int = 2) -> RaceResult:
    """Race ``candidates`` to the scenario's full replicate budget, culling
    dominated configs early. Returns every candidate's evidence (culled ones
    keep the seeds they saw), the full-budget survivors, and the spend."""
    n_seeds = scenario.n_seeds
    n = len(candidates)
    if n == 0:
        raise ValueError("race needs at least one candidate")
    init_seeds = int(np.clip(init_seeds, 1, n_seeds))
    evals = [None] * n
    alive = list(range(n))
    culled_at = {}
    sims = 0
    s_done = 0               # replicates every live candidate has seen
    rnd = 0
    while s_done < n_seeds:
        s_next = min(max(s_done * eta, init_seeds), n_seeds)
        with telemetry.span("tune.race.round", round=rnd, alive=len(alive),
                            s0=s_done, s1=s_next):
            fresh = evaluate_candidates(
                scenario, [candidates[i] for i in alive], objective,
                s0=s_done, s1=s_next)
        sims += len(alive) * (s_next - s_done)
        for i, ev in zip(alive, fresh):
            if evals[i] is None:
                evals[i] = ev
            else:
                evals[i].extend(ev)
            evals[i].n_rounds = rnd + 1
        s_done = s_next

        if len(alive) > 1:
            with telemetry.span("tune.race.cull", round=rnd):
                by_score = sorted(alive, key=lambda i: evals[i].mean_score())
                inc = evals[by_score[0]]
                keep = [by_score[0]]
                for i in by_score[1:]:
                    if _sprt_cull(evals[i].score - inc.score, alpha, beta):
                        culled_at[i] = rnd
                        telemetry.counter("tuning_culled_total", reason="sprt")
                    else:
                        keep.append(i)
                # successive halving on top of the SPRT: even when the test is
                # inconclusive for many candidates, at most ceil(|alive|/eta)
                # advance to the next (eta-x costlier) rung
                cap = max(int(np.ceil(len(alive) / eta)), min_survivors)
                if s_done < n_seeds and len(keep) > cap:
                    for i in keep[cap:]:
                        culled_at[i] = rnd
                        telemetry.counter("tuning_culled_total",
                                          reason="halving")
                    keep = keep[:cap]
            alive = keep
        rnd += 1
        if len(alive) == 1 and s_done < n_seeds:
            # a lone survivor still gets its full-budget evaluation (the
            # winner's headline numbers must use every replicate)
            with telemetry.span("tune.race.round", round=rnd,
                                alive=1, s0=s_done, s1=n_seeds):
                fresh = evaluate_candidates(
                    scenario, [candidates[alive[0]]], objective,
                    s0=s_done, s1=n_seeds)
            sims += n_seeds - s_done
            evals[alive[0]].extend(fresh[0])
            evals[alive[0]].n_rounds = rnd + 1
            s_done = n_seeds

    survivors = [evals[i] for i in alive]
    winner = min(survivors, key=lambda e: e.mean_score())
    return RaceResult(evals=[e for e in evals if e is not None],
                      winner=winner, survivors=survivors, sims_used=sims,
                      full_budget=n * n_seeds, culled_at_round=culled_at)


def exhaustive(scenario: TuningScenario, candidates: list,
               objective: Objective) -> RaceResult:
    """The naive full-budget sweep: every candidate on every replicate.
    The reference racing is measured against."""
    evals = evaluate_candidates(scenario, candidates, objective)
    winner = min(evals, key=lambda e: e.mean_score())
    full = len(candidates) * scenario.n_seeds
    return RaceResult(evals=evals, winner=winner, survivors=list(evals),
                      sims_used=full, full_budget=full)
