"""Successive-halving candidate racing with SPRT-flavored early culling.

The naive sweep spends ``n_candidates x n_seeds`` simulations; most of that
budget goes to configs that are obviously dominated after two replicates.
Racing spends the budget where the decision is actually close:

* rounds double the replicate count (seed slices are *shared* across
  candidates, so per-seed score differences vs the incumbent are paired —
  ``evaluate.py``'s common-random-numbers setup; on the jax backend each
  round's surviving slate is scored as ONE compiled candidate x seed batch,
  with ``sims_used`` accounting unchanged);
* a candidate is culled early when the sequential log-likelihood ratio of its
  paired score deficit vs the incumbent crosses the Wald threshold
  ``ln((1-beta)/alpha)`` — the same two-hypothesis sequential test
  ``mset/sprt.py`` runs on MSET residuals, here on "is this config worse than
  the incumbent by at least one per-seed noise sigma?";
* independently of the SPRT, each round keeps at most ``ceil(n / eta)``
  survivors (classic successive halving), which bounds total spend at a small
  multiple of ``n_candidates x init_seeds`` regardless of how noisy the
  scenario is.

The full-budget reference (``exhaustive``) exists for benchmarking the
racer: on the seeded scenarios the tests pin, racing returns the same winner
for <= 40% of the exhaustive simulation budget.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.fleet import telemetry
from repro.fleet.tuning.evaluate import (CandidateEval, Objective,
                                         TuningScenario,
                                         evaluate_candidates,
                                         evaluate_candidates_column)

_EPS = 1e-12


@dataclass
class RaceResult:
    evals: list                      # CandidateEval per candidate (all)
    winner: CandidateEval
    survivors: list                  # full-budget finalists (CandidateEval)
    sims_used: int                   # candidate x seed x trace sims spent
    full_budget: int                 # n_candidates x n_seeds x n_traces
    culled_at_round: dict = field(default_factory=dict)  # cand idx -> round

    @property
    def budget_frac(self) -> float:
        return self.sims_used / max(self.full_budget, 1)


def _sprt_cull(deficits: np.ndarray, alpha: float, beta: float) -> bool:
    """Wald SPRT on paired per-seed score deficits d_i = cand_i - incumbent_i.

    H0: the candidate matches the incumbent (mean deficit 0); H1: it is worse
    by one per-seed noise sigma. With the effect size theta = sigma the
    log-likelihood ratio after n paired replicates reduces to
    ``sum(d)/sigma - n/2``; cull when it crosses ``ln((1-beta)/alpha)``.
    Degenerate spread (paired deficits all but equal) short-circuits on the
    sign: deterministically worse is culled, deterministically tied kept.
    """
    d = np.asarray(deficits, float)
    n = len(d)
    if n < 2:
        return False
    sigma = float(d.std(ddof=1))
    if sigma < _EPS:
        return bool(d.mean() > _EPS)
    llr = float(d.sum()) / sigma - n / 2.0
    return llr >= np.log((1.0 - beta) / alpha)


def race(scenario: TuningScenario, candidates: list, objective: Objective,
         *, init_seeds: int = 2, eta: int = 2, alpha: float = 0.05,
         beta: float = 0.05, min_survivors: int = 2) -> RaceResult:
    """Race ``candidates`` to the scenario's full replicate budget, culling
    dominated configs early. Returns every candidate's evidence (culled ones
    keep the seeds they saw), the full-budget survivors, and the spend. On a
    portfolio scenario racing operates on the robust per-seed score and each
    replicate costs ``n_traces`` simulations (all ridden by the same
    dispatch), so ``sims_used``/``full_budget`` count
    candidate x seed x trace trajectories."""
    n_seeds = scenario.n_seeds
    n = len(candidates)
    K = scenario.n_traces
    if n == 0:
        raise ValueError("race needs at least one candidate")
    init_seeds = int(np.clip(init_seeds, 1, n_seeds))
    evals = [None] * n
    alive = list(range(n))
    culled_at = {}
    sims = 0
    s_done = 0               # replicates every live candidate has seen
    rnd = 0
    while s_done < n_seeds:
        s_next = min(max(s_done * eta, init_seeds), n_seeds)
        with telemetry.span("tune.race.round", round=rnd, alive=len(alive),
                            s0=s_done, s1=s_next):
            fresh = evaluate_candidates(
                scenario, [candidates[i] for i in alive], objective,
                s0=s_done, s1=s_next)
        sims += len(alive) * (s_next - s_done) * K
        for i, ev in zip(alive, fresh):
            if evals[i] is None:
                evals[i] = ev
            else:
                evals[i].extend(ev)
            evals[i].n_rounds = rnd + 1
        s_done = s_next

        if len(alive) > 1:
            with telemetry.span("tune.race.cull", round=rnd):
                by_score = sorted(alive, key=lambda i: evals[i].mean_score())
                inc = evals[by_score[0]]
                keep = [by_score[0]]
                for i in by_score[1:]:
                    if _sprt_cull(evals[i].score - inc.score, alpha, beta):
                        culled_at[i] = rnd
                        telemetry.counter("tuning_culled_total", reason="sprt")
                    else:
                        keep.append(i)
                # successive halving on top of the SPRT: even when the test is
                # inconclusive for many candidates, at most ceil(|alive|/eta)
                # advance to the next (eta-x costlier) rung
                cap = max(int(np.ceil(len(alive) / eta)), min_survivors)
                if s_done < n_seeds and len(keep) > cap:
                    for i in keep[cap:]:
                        culled_at[i] = rnd
                        telemetry.counter("tuning_culled_total",
                                          reason="halving")
                    keep = keep[:cap]
            alive = keep
        rnd += 1
        if len(alive) == 1 and s_done < n_seeds:
            # a lone survivor still gets its full-budget evaluation (the
            # winner's headline numbers must use every replicate)
            with telemetry.span("tune.race.round", round=rnd,
                                alive=1, s0=s_done, s1=n_seeds):
                fresh = evaluate_candidates(
                    scenario, [candidates[alive[0]]], objective,
                    s0=s_done, s1=n_seeds)
            sims += (n_seeds - s_done) * K
            evals[alive[0]].extend(fresh[0])
            evals[alive[0]].n_rounds = rnd + 1
            s_done = n_seeds

    survivors = [evals[i] for i in alive]
    winner = min(survivors, key=lambda e: e.mean_score())
    return RaceResult(evals=[e for e in evals if e is not None],
                      winner=winner, survivors=survivors, sims_used=sims,
                      full_budget=n * n_seeds * K, culled_at_round=culled_at)


def exhaustive(scenario: TuningScenario, candidates: list,
               objective: Objective) -> RaceResult:
    """The naive full-budget sweep: every candidate on every replicate (and,
    on a portfolio, every trace). The reference racing is measured against."""
    evals = evaluate_candidates(scenario, candidates, objective)
    winner = min(evals, key=lambda e: e.mean_score())
    full = len(candidates) * scenario.n_seeds * scenario.n_traces
    return RaceResult(evals=evals, winner=winner, survivors=list(evals),
                      sims_used=full, full_budget=full)


def race_column(scenario: TuningScenario, candidates: list,
                objective: Objective, slo_values, *, init_seeds: int = 2,
                eta: int = 2, alpha: float = 0.05, beta: float = 0.05,
                min_survivors: int = 2):
    """Race one shared candidate slate for a whole column of SLO tiers,
    every round ONE compiled dispatch over the union of tier-alive
    candidates (``evaluate_candidates_column``: single-class tiers share
    bin-exact dynamics, only the host-side SLO accounting differs).

    Each tier runs ``race``'s exact bookkeeping — same rung schedule, SPRT
    cull, halving cap and full-budget winner evidence — against its own SLO
    bar, so per-tier winners, survivors and per-tier ``sims_used`` are
    identical to racing each tier separately; what changes is the physical
    spend: a candidate alive in several tiers simulates once per round, not
    once per tier. Returns ``(results, sims_shared)`` — per-tier
    ``RaceResult``s aligned with ``slo_values`` plus the actual shared
    trajectory count — or ``None`` when the slate cannot batch (caller
    races tiers separately)."""
    n_seeds = scenario.n_seeds
    n = len(candidates)
    K = scenario.n_traces
    if n == 0:
        raise ValueError("race needs at least one candidate")
    n_tiers = len(slo_values)
    init_seeds = int(np.clip(init_seeds, 1, n_seeds))
    evals = [[None] * n for _ in range(n_tiers)]
    alive = [list(range(n)) for _ in range(n_tiers)]
    culled_at = [{} for _ in range(n_tiers)]
    tier_sims = [0] * n_tiers
    sims_shared = 0
    s_done = 0
    rnd = 0
    while s_done < n_seeds:
        s_next = min(max(s_done * eta, init_seeds), n_seeds)
        union = sorted(set().union(*map(set, alive)))
        with telemetry.span("tune.race.round", round=rnd, alive=len(union),
                            s0=s_done, s1=s_next, tiers=n_tiers):
            tiered = evaluate_candidates_column(
                scenario, [candidates[i] for i in union], objective,
                slo_values, s0=s_done, s1=s_next)
        if tiered is None:
            return None
        sims_shared += len(union) * (s_next - s_done) * K
        pos = {i: j for j, i in enumerate(union)}
        for ti in range(n_tiers):
            ev_t, alive_t = evals[ti], alive[ti]
            tier_sims[ti] += len(alive_t) * (s_next - s_done) * K
            for i in alive_t:
                ev = tiered[ti][pos[i]]
                if ev_t[i] is None:
                    ev_t[i] = ev
                else:
                    ev_t[i].extend(ev)
                ev_t[i].n_rounds = rnd + 1
        s_done = s_next

        for ti in range(n_tiers):
            ev_t, alive_t = evals[ti], alive[ti]
            if len(alive_t) <= 1:
                continue
            with telemetry.span("tune.race.cull", round=rnd, tier=ti):
                by_score = sorted(alive_t,
                                  key=lambda i: ev_t[i].mean_score())
                inc = ev_t[by_score[0]]
                keep = [by_score[0]]
                for i in by_score[1:]:
                    if _sprt_cull(ev_t[i].score - inc.score, alpha, beta):
                        culled_at[ti][i] = rnd
                        telemetry.counter("tuning_culled_total",
                                          reason="sprt")
                    else:
                        keep.append(i)
                cap = max(int(np.ceil(len(alive_t) / eta)), min_survivors)
                if s_done < n_seeds and len(keep) > cap:
                    for i in keep[cap:]:
                        culled_at[ti][i] = rnd
                        telemetry.counter("tuning_culled_total",
                                          reason="halving")
                    keep = keep[:cap]
            alive[ti] = keep
        rnd += 1
        # a tier's lone survivor keeps riding the shared rounds to the full
        # replicate budget (adjacent slices concatenate to exactly the
        # one-shot evidence ``race``'s fast path collects)

    results = []
    for ti in range(n_tiers):
        survivors = [evals[ti][i] for i in alive[ti]]
        winner = min(survivors, key=lambda e: e.mean_score())
        results.append(RaceResult(
            evals=[e for e in evals[ti] if e is not None], winner=winner,
            survivors=survivors, sims_used=tier_sims[ti],
            full_budget=n * n_seeds * K, culled_at_round=culled_at[ti]))
    return results, sims_shared
