"""TPSS — Telemetry Parameter Synthesis System (paper refs [7-9]).

Synthesizes dense-sensor IoT telemetry that matches real signals in the statistics
that matter to ML prognostics (paper §II.C):

* serial correlation   — AR(2) innovations + deterministic harmonics (duty cycles)
* cross correlation    — signals mixed through a random low-rank + diagonal loading
                         matrix (Cholesky of a valid correlation matrix)
* stochastic content   — per-signal variance; skew/kurtosis shaped with a
                         sinh-arcsinh transform

Everything is jax.random-driven and jit-compatible: one (key, params) -> (n_obs,
n_signals) f32 array, deterministic per key (the Monte Carlo loop draws keys).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

F32 = jnp.float32


@dataclass(frozen=True)
class TPSSParams:
    n_signals: int
    n_obs: int
    ar1: float = 0.85            # AR(2) coefficients (stable: ar1+ar2<1)
    ar2: float = -0.10
    n_harmonics: int = 3
    harmonic_amp: float = 0.6
    cross_rank: int = 4          # rank of the shared latent factors
    cross_weight: float = 0.5    # 0 = independent, 1 = fully shared
    skew: float = 0.15           # sinh-arcsinh skew parameter (0 = symmetric)
    tailweight: float = 1.05     # sinh-arcsinh tail weight (1 = gaussian kurtosis)
    mean_scale: float = 10.0
    std_scale: float = 1.0


def _ar2(key, n_obs: int, n_series: int, a1: float, a2: float) -> jax.Array:
    eps = jax.random.normal(key, (n_obs, n_series), F32)

    def step(carry, e):
        y1, y2 = carry
        y = a1 * y1 + a2 * y2 + e
        return (y, y1), y

    _, ys = lax.scan(step, (jnp.zeros(n_series, F32), jnp.zeros(n_series, F32)), eps)
    # normalize to unit variance (theoretical AR(2) variance)
    denom = (1 + a2) * ((1 - a2) ** 2 - a1 ** 2) / (1 - a2)
    std = math.sqrt(1.0 / max(denom, 1e-6))
    return ys / std


def _sinh_arcsinh(x, skew: float, tail: float):
    """Jones-Pewsey sinh-arcsinh: shapes skewness/kurtosis, identity at (0, 1)."""
    return jnp.sinh(tail * jnp.arcsinh(x) + skew)


def synthesize(key, p: TPSSParams) -> jax.Array:
    """Return (n_obs, n_signals) synthesized telemetry."""
    k_ar, k_lat, k_mix, k_phase, k_freq, k_mean, k_std = jax.random.split(key, 7)

    # serially-correlated stochastic content: own AR(2) + shared latent AR(2)
    own = _ar2(k_ar, p.n_obs, p.n_signals, p.ar1, p.ar2)
    lat = _ar2(k_lat, p.n_obs, p.cross_rank, p.ar1, p.ar2)
    mix = jax.random.normal(k_mix, (p.cross_rank, p.n_signals), F32)
    mix = mix / jnp.linalg.norm(mix, axis=0, keepdims=True)
    shared = lat @ mix
    w = p.cross_weight
    noise = math.sqrt(1 - w * w) * own + w * shared

    # deterministic harmonics (mission/duty cycles)
    t = jnp.arange(p.n_obs, dtype=F32)[:, None]
    freqs = jax.random.uniform(k_freq, (p.n_harmonics, p.n_signals), F32,
                               2 * math.pi / p.n_obs * 2, 2 * math.pi / 64)
    phase = jax.random.uniform(k_phase, (p.n_harmonics, p.n_signals), F32,
                               0, 2 * math.pi)
    harm = jnp.zeros((p.n_obs, p.n_signals), F32)
    for h in range(p.n_harmonics):
        harm = harm + jnp.sin(t * freqs[h][None, :] + phase[h][None, :])
    harm = harm * (p.harmonic_amp / max(p.n_harmonics, 1))

    x = _sinh_arcsinh(noise, p.skew, p.tailweight) + harm

    mean = jax.random.normal(k_mean, (p.n_signals,), F32) * p.mean_scale
    std = jnp.exp(jax.random.normal(k_std, (p.n_signals,), F32) * 0.3) * p.std_scale
    return x * std[None, :] + mean[None, :]


def synthesize_batch(key, p: TPSSParams, n_assets: int) -> jax.Array:
    """(n_assets, n_obs, n_signals) — a fleet of similar-but-distinct assets."""
    keys = jax.random.split(key, n_assets)
    return jax.vmap(lambda k: synthesize(k, p))(keys)


def inject_anomaly(x, start: int, signal: int, drift_per_step: float):
    """Additive ramp drift on one signal from `start` (classic incipient fault)."""
    n = x.shape[0]
    t = jnp.arange(n, dtype=F32)
    ramp = jnp.where(t >= start, (t - start) * drift_per_step, 0.0)
    return x.at[:, signal].add(ramp)
