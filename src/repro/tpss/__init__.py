from repro.tpss.synth import TPSSParams, inject_anomaly, synthesize, synthesize_batch

__all__ = ["TPSSParams", "synthesize", "synthesize_batch", "inject_anomaly"]
