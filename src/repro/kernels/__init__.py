# Compute hot-spots the paper optimizes: the MSET2 similarity operator is the
# paper's named CUDA kernel (Fig. 3) -> Pallas MXU-tiled similarity kernel; the
# 32k-prefill attention of the LM fleet gets a causal flash-attention kernel.
from repro.kernels.attention import flash_attention, gqa_attention, mha_ref
from repro.kernels.similarity import similarity, similarity_pallas, similarity_ref

__all__ = ["similarity", "similarity_pallas", "similarity_ref",
           "flash_attention", "gqa_attention", "mha_ref"]
