"""Pallas TPU causal flash attention (online softmax, streaming KV blocks).

Targets the 32k-prefill hot spot. Grid: (B*H, nq, nkv) with the KV dimension
innermost; running max/denominator/accumulator live in VMEM scratch that persists
across the sequential innermost grid steps (TPU 'arbitrary' dimension semantics).
Causal skipping: KV blocks strictly above the diagonal are masked out (their
contribution underflows to zero in the online rescale).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            nkv: int, bq: int, bkv: int, scale: float, causal: bool,
            kv_len: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(F32)                       # (bq, hd)
    k = k_ref[0].astype(F32)                       # (bkv, hd)
    v = v_ref[0].astype(F32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=F32) * scale   # (bq, bkv)
    cols = j * bkv + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 1)
    mask = cols < kv_len                           # mask padded keys
    if causal:
        rows = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bkv), 0)
        mask = mask & (cols <= rows)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_scr[...] + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=F32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(j == nkv - 1)
    def _emit():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bkv: int = 128, interpret: bool = False):
    """q/k/v: (B, S, H, hd), equal head counts (wrapper expands GQA).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    bq_, bkv_ = min(bq, S), min(bkv, S)
    Sp = _rup(S, max(bq_, bkv_))
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    # (B, S, H, hd) -> (B*H, S, hd)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)

    nq, nkv = Sp // bq_, Sp // bkv_
    grid = (B * H, nq, nkv)
    out = pl.pallas_call(
        functools.partial(_kernel, nkv=nkv, bq=bq_, bkv=bkv_, scale=scale,
                          causal=causal, kv_len=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv_, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv_, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), F32),      # running max
            pltpu.VMEM((bq_, 1), F32),      # running denominator
            pltpu.VMEM((bq_, hd), F32),     # output accumulator
        ],
        interpret=interpret,
    )(qt, kt, vt)
    out = out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)
    return out[:, :S]


def _rup(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult
