from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ops import gqa_attention
from repro.kernels.attention.ref import mha_ref

__all__ = ["flash_attention", "gqa_attention", "mha_ref"]
