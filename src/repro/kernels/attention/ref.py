"""Pure-jnp oracle for causal flash attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def mha_ref(q, k, v, causal: bool = True, scale=None):
    """q/k/v: (B, S, H, hd) (same head count; GQA is expanded by the wrapper).
    Returns (B, S, H, hd)."""
    B, S, H, hd = q.shape
    scale = scale or (hd ** -0.5)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(F32), k.astype(F32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w, v.astype(F32)).astype(q.dtype)
