"""Jit'd GQA wrapper around the flash-attention kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.attention.flash import flash_attention
from repro.kernels.attention.ref import mha_ref


@partial(jax.jit, static_argnames=("causal", "impl"))
def gqa_attention(q, k, v, *, causal: bool = True, impl: str = "auto"):
    """q: (B, S, H, hd); k/v: (B, S, K, hd) with H % K == 0."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    if K != H:
        rep = H // K
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return flash_attention(q, k, v, causal=causal)
    if impl == "interpret":
        return flash_attention(q, k, v, causal=causal, interpret=True)
    return mha_ref(q, k, v, causal=causal)
