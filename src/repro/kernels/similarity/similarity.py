"""Pallas TPU kernel for the MSET2 pairwise-similarity operator.

TPU adaptation of the paper's CUDA similarity kernel (Figure 3): the CUDA
grid/block/warp/thread hierarchy becomes BlockSpec VMEM tiling around the 128x128
MXU. The Euclidean distance is rewritten as ||x||^2 + ||y||^2 - 2 x.y^T so the
dominant cost is an MXU matmul streamed over the signal dimension in K-blocks,
with a fused VPU epilogue applying the nonlinearity — one HBM pass over x and y,
no (m x b x n) intermediate.

Grid: (m/bm, b/bn, n/bk), K innermost; the f32 output block doubles as the
accumulator across K steps (revisited blocks stay resident in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

F32 = jnp.float32


def _kernel(x_ref, y_ref, x2_ref, y2_ref, o_ref, *, nk: int, gamma: float, kind: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xb = x_ref[...].astype(F32)          # (bm, bk)
    yb = y_ref[...].astype(F32)          # (bn, bk)
    o_ref[...] += jax.lax.dot_general(
        xb, yb, (((1,), (1,)), ((), ())), preferred_element_type=F32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        d2 = x2_ref[...][:, None] + y2_ref[...][None, :] - 2.0 * acc
        d2 = jnp.maximum(d2, 0.0)
        if kind == "inverse_distance":
            o_ref[...] = 1.0 / (1.0 + jnp.sqrt(d2) * (1.0 / gamma))
        else:  # gaussian
            o_ref[...] = jnp.exp(d2 * (-1.0 / (2.0 * gamma * gamma)))


def similarity_pallas(x, y, gamma: float = 1.0, kind: str = "inverse_distance",
                      *, bm: int = 256, bn: int = 256, bk: int = 512,
                      interpret: bool = False):
    """x: (m, n), y: (b, n) -> (m, b) f32 similarity matrix.

    Shapes are padded to block multiples; padding contributes d2=0 terms that are
    sliced away (norms of zero-padded tails are zero, so distances are exact).
    """
    m, n = x.shape
    b, n2 = y.shape
    assert n == n2, (x.shape, y.shape)
    bm_, bn_, bk_ = min(bm, _rup(m, 8)), min(bn, _rup(b, 128)), min(bk, _rup(n, 128))
    mp, bp, np_ = _rup(m, bm_), _rup(b, bn_), _rup(n, bk_)

    xp = jnp.pad(x, ((0, mp - m), (0, np_ - n)))
    yp = jnp.pad(y, ((0, bp - b), (0, np_ - n)))
    x2 = jnp.sum(xp.astype(F32) ** 2, axis=-1)
    y2 = jnp.sum(yp.astype(F32) ** 2, axis=-1)

    nk = np_ // bk_
    grid = (mp // bm_, bp // bn_, nk)
    out = pl.pallas_call(
        functools.partial(_kernel, nk=nk, gamma=float(gamma), kind=kind),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn_, bk_), lambda i, j, k: (j, k)),
            pl.BlockSpec((bm_,), lambda i, j, k: (i,)),
            pl.BlockSpec((bn_,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, bp), F32),
        interpret=interpret,
    )(xp, yp, x2, y2)
    return out[:m, :b]


def _rup(v: int, mult: int) -> int:
    return ((v + mult - 1) // mult) * mult
