"""Jit'd public wrapper for the similarity kernel.

On TPU backends this lowers the Pallas kernel; on CPU (this dev container) it
runs the kernel in interpret mode when explicitly requested, or the jnp
reference — both produce identical values (tested).
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels.similarity.ref import similarity_ref
from repro.kernels.similarity.similarity import similarity_pallas


@partial(jax.jit, static_argnames=("gamma", "kind", "impl"))
def similarity(x, y, *, gamma: float = 1.0, kind: str = "inverse_distance",
               impl: str = "auto"):
    """Pairwise similarity S = h(dist(x, y)). impl: auto|pallas|interpret|ref."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "ref"
    if impl == "pallas":
        return similarity_pallas(x, y, gamma, kind)
    if impl == "interpret":
        return similarity_pallas(x, y, gamma, kind, interpret=True)
    return similarity_ref(x, y, gamma, kind)
