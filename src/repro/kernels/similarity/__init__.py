from repro.kernels.similarity.ops import similarity
from repro.kernels.similarity.ref import similarity_ref
from repro.kernels.similarity.similarity import similarity_pallas

__all__ = ["similarity", "similarity_ref", "similarity_pallas"]
