"""Pure-jnp oracle for the pairwise similarity operator (MSET2 hot spot)."""
from __future__ import annotations

import jax.numpy as jnp

F32 = jnp.float32


def similarity_ref(x, y, gamma: float = 1.0, kind: str = "inverse_distance"):
    """S[i, j] = h(||x_i - y_j||). x: (m, n), y: (b, n) -> (m, b) f32.

    kind:
      inverse_distance — 1 / (1 + d / gamma)          (MSET-style nonlinear op)
      gaussian         — exp(-d^2 / (2 gamma^2))      (AAKR kernel)
    """
    xf, yf = x.astype(F32), y.astype(F32)
    x2 = jnp.sum(xf * xf, axis=-1)[:, None]
    y2 = jnp.sum(yf * yf, axis=-1)[None, :]
    d2 = jnp.maximum(x2 + y2 - 2.0 * (xf @ yf.T), 0.0)
    if kind == "inverse_distance":
        return 1.0 / (1.0 + jnp.sqrt(d2) / gamma)
    if kind == "gaussian":
        return jnp.exp(-d2 / (2.0 * gamma * gamma))
    raise ValueError(f"unknown similarity kind {kind!r}")
