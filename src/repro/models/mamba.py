"""Mamba2 / SSD (state-space duality) block.

Train/prefill use the chunked SSD algorithm (intra-chunk quadratic on the MXU +
inter-chunk linear state scan); decode uses the O(1) recurrent update. State math
in fp32; projections in the model dtype.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import Box, ShardingRules
from repro.models import layers

F32 = jnp.float32


def conv_channels(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_ngroups * cfg.ssm_state


def init_ssd(cfg: ArchConfig, key):
    d, din = cfg.d_model, cfg.d_inner
    H, G, N = cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state
    proj_out = 2 * din + 2 * G * N + H           # z, x, B, C, dt
    cch = conv_channels(cfg)
    ks = jax.random.split(key, 5)
    dt_bias = jnp.log(jnp.expm1(jnp.exp(jax.random.uniform(
        ks[3], (H,), F32, math.log(1e-3), math.log(1e-1)))))
    a_init = jax.random.uniform(ks[4], (H,), F32, 1.0, 16.0)
    return {
        "w_in": layers.dense_init(ks[0], (d, proj_out), ("embed", "ssm_inner")),
        "conv_w": layers.dense_init(ks[1], (cfg.conv_width, cch), ("conv", "ssm_inner"),
                                    scale=1.0),
        "conv_b": layers.zeros_init((cch,), ("ssm_inner",)),
        "A_log": Box(jnp.log(a_init), ("ssm_heads",)),
        "D": layers.ones_init((H,), ("ssm_heads",)),
        "dt_bias": Box(dt_bias, ("ssm_heads",)),
        "norm": layers.ones_init((din,), ("ssm_inner",)),
        "w_out": layers.dense_init(ks[2], (din, d), ("ssm_inner", "embed")),
    }


def _split_proj(cfg: ArchConfig, zxbcdt):
    din, G, N, H = cfg.d_inner, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din: 2 * din + 2 * G * N]
    dt = zxbcdt[..., 2 * din + 2 * G * N:]
    return z, xbc, dt


def _causal_conv(cfg: ArchConfig, p, xbc):
    """Depthwise causal conv over (B, S, C) with width W."""
    W = cfg.conv_width
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    acc = None
    for i in range(W):
        term = pad[:, i: i + xbc.shape[1], :] * p["conv_w"][i].astype(xbc.dtype)
        acc = term if acc is None else acc + term
    return jax.nn.silu(acc + p["conv_b"].astype(xbc.dtype))


def ssd_chunked(cfg: ArchConfig, xh, dt, A, Bm, Cm, init_state=None):
    """Chunked SSD scan.

    xh: (B, S, H, P) inputs per head; dt: (B, S, H) softplus'd step sizes;
    A: (H,) negative decay rates; Bm/Cm: (B, S, G, N).
    Returns y (B, S, H, P) and final state (B, H, P, N) in fp32.
    """
    Bsz, S, H, Pd = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssd_chunk, S)
    S_orig = S
    if S % Q != 0:
        # pad with dt=0 steps: zero contribution, unit decay => exact
        pad = Q - S % Q
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // Q
    rep = H // G

    xf = xh.astype(F32).reshape(Bsz, nc, Q, H, Pd)
    dtf = dt.astype(F32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(F32).reshape(Bsz, nc, Q, G, N)
    Cf = Cm.astype(F32).reshape(Bsz, nc, Q, G, N)

    dA = dtf * A[None, None, None, :]                       # (B, nc, Q, H) (negative)
    ca = jnp.cumsum(dA, axis=2)                             # within-chunk cumsum
    ca_last = ca[:, :, -1:, :]                              # (B, nc, 1, H)

    # ---- intra-chunk (quadratic within Q; MXU einsums) ----
    Bh = jnp.repeat(Bf, rep, axis=3)                        # (B, nc, Q, H, N)
    Ch = jnp.repeat(Cf, rep, axis=3)
    gates = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh)        # q=i, k=j
    # L[i,j] = exp(ca_i - ca_j) for i >= j else 0
    ci = ca.transpose(0, 1, 3, 2)                           # (B, nc, H, Q)
    ldiff = ci[..., :, None] - ci[..., None, :]             # (B, nc, H, Q, Q)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    # zero masked exponents BEFORE exp: upper-triangle ldiff is large-positive
    # (ca is decreasing), exp -> inf, and where()'s VJP would turn inf*0 into
    # NaN gradients (reproduced at full 130M scale; see test_property.py)
    L = jnp.where(mask, jnp.exp(jnp.where(mask, ldiff, 0.0)), 0.0)
    M = gates * L * dtf.transpose(0, 1, 3, 2)[..., None, :]  # * dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", M, xf)

    # ---- chunk states: S_c = sum_j exp(ca_last - ca_j) dt_j B_j x_j^T ----
    w = jnp.exp(ca_last - ca) * dtf                         # (B, nc, Q, H)
    states = jnp.einsum("bcqh,bcqhn,bcqhp->bchnp", w, Bh, xf)  # (B, nc, H, N, P)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(ca_last[:, :, 0, :])              # (B, nc, H)

    def scan_body(carry, inp):
        s_c, d_c = inp                                       # (B,H,N,P), (B,H)
        new = carry * d_c[..., None, None] + s_c
        return new, carry                                    # emit state BEFORE chunk

    s0 = jnp.zeros((Bsz, H, N, Pd), F32) if init_state is None else init_state
    states_t = states.transpose(1, 0, 2, 3, 4)
    decay_t = chunk_decay.transpose(1, 0, 2)
    if cfg.unroll:
        carry, prevs = s0, []
        for c in range(nc):
            carry, prev = scan_body(carry, (states_t[c], decay_t[c]))
            prevs.append(prev)
        final, prev_states = carry, jnp.stack(prevs, axis=0)
    else:
        final, prev_states = lax.scan(scan_body, s0, (states_t, decay_t))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)       # (B, nc, H, N, P)

    # ---- inter-chunk contribution: y_i += C_i · (exp(ca_i) * state_prev) ----
    inter_w = jnp.exp(ca)                                    # (B, nc, Q, H)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, prev_states, inter_w)

    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)[:, :S_orig]
    return y, final


def apply_ssd(cfg: ArchConfig, p, x, rules: ShardingRules, cache=None, pos=None):
    """Full SSD block. Train/prefill when cache is None or pos is None is handled
    by the caller convention:
      * cache is None           -> train path, returns (y, None)
      * cache given, pos None   -> prefill: run chunked scan, return final caches
      * cache given, pos given  -> single-token decode
    """
    dt_m = x.dtype
    din, H, G, N = cfg.d_inner, cfg.ssm_nheads, cfg.ssm_ngroups, cfg.ssm_state
    Pd = cfg.ssm_headdim
    W = cfg.conv_width

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(dt_m))
    zxbcdt = rules.constrain(zxbcdt, ("batch", "act_seq", "act_mlp"))
    z, xbc, dtr = _split_proj(cfg, zxbcdt)

    if cache is not None and pos is not None:
        # ---- decode: recurrent update ----
        # conv cache: (B, W-1, cch) rolling window of pre-activation inputs
        xbc_t = xbc[:, 0, :]                                 # (B, cch)
        window = jnp.concatenate([cache["conv"], xbc_t[:, None, :]], axis=1)  # (B, W, cch)
        conv_out = jnp.einsum("bwc,wc->bc", window.astype(F32),
                              p["conv_w"].astype(F32)) + p["conv_b"].astype(F32)
        conv_out = jax.nn.silu(conv_out)
        xh = conv_out[:, :din].reshape(-1, H, Pd)            # (B, H, P)
        Bm = conv_out[:, din:din + G * N].reshape(-1, G, N)
        Cm = conv_out[:, din + G * N:].reshape(-1, G, N)
        rep = H // G
        Bh = jnp.repeat(Bm, rep, axis=1)                     # (B, H, N)
        Ch = jnp.repeat(Cm, rep, axis=1)
        dtv = jax.nn.softplus(dtr[:, 0, :].astype(F32) + p["dt_bias"][None])  # (B, H)
        A = -jnp.exp(p["A_log"])                             # (H,)
        dA = jnp.exp(dtv * A[None])                          # (B, H)
        state = cache["state"]                               # (B, H, N, P) fp32
        upd = jnp.einsum("bh,bhn,bhp->bhnp", dtv, Bh, xh)
        state = state * dA[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", Ch, state)
        y = y + p["D"].astype(F32)[None, :, None] * xh
        y = y.reshape(-1, 1, din).astype(dt_m)
        y = layers.rms_norm_nohead(y * jax.nn.silu(z.astype(F32)).astype(dt_m), p["norm"])
        out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_m))
        new_cache = {"conv": window[:, 1:, :], "state": state}
        return out, new_cache

    # ---- train / prefill: chunked scan ----
    xbc = _causal_conv(cfg, p, xbc)
    xh = xbc[..., :din].reshape(*xbc.shape[:2], H, Pd)
    Bm = xbc[..., din:din + G * N].reshape(*xbc.shape[:2], G, N)
    Cm = xbc[..., din + G * N:].reshape(*xbc.shape[:2], G, N)
    xh = rules.constrain(xh, ("batch", "act_seq", "ssm_heads", None))
    dtv = jax.nn.softplus(dtr.astype(F32) + p["dt_bias"][None, None])
    A = -jnp.exp(p["A_log"])
    y, final_state = ssd_chunked(cfg, xh, dtv, A, Bm, Cm)
    y = y.astype(dt_m)
    y = y + (p["D"].astype(dt_m)[None, None, :, None] * xh)
    y = y.reshape(*x.shape[:2], din)
    y = layers.rms_norm_nohead(y * jax.nn.silu(z.astype(F32)).astype(dt_m), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"].astype(dt_m))

    new_cache = None
    if cache is not None:
        # prefill: stash conv window (last W-1 pre-conv inputs) + final state
        _, xbc_raw, _ = _split_proj(cfg, zxbcdt)
        new_cache = {"conv": xbc_raw[:, -(W - 1):, :], "state": final_state}
    return out, new_cache


def cache_spec(cfg: ArchConfig, batch: int):
    """ShapeDtypeStruct Box tree for SSD decode cache."""
    cch = conv_channels(cfg)
    H, N, Pd = cfg.ssm_nheads, cfg.ssm_state, cfg.ssm_headdim
    return {
        "conv": Box(jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, cch), jnp.dtype(cfg.dtype)),
                    ("cache_batch", None, "ssm_inner")),
        "state": Box(jax.ShapeDtypeStruct((batch, H, N, Pd), F32),
                     ("cache_batch", "ssm_heads", None, None)),
    }
