"""Mixture-of-Experts FFN.

Two dispatch implementations, both FLOP-faithful (no dense all-experts compute):

* ``gather`` — global sort-free grouped dispatch via cumsum-ranked scatter into an
  (E, C) buffer. Exact when capacity suffices; used for decode (few tokens) and
  smoke tests. GSPMD shards the expert einsum over the ``model`` axis.
* ``ep`` — expert parallelism via ``shard_map``: tokens are split over the
  (pod·data) batch axes *and* the ``model`` axis (sequence split), routed locally,
  exchanged with ``all_to_all`` to the expert-owner shards, computed, and returned.
  This is the production path for train/prefill and makes the MoE collective
  schedule (2× all_to_all + all_gather) explicit in the HLO.

Experts are padded to a multiple of the model-axis size when necessary
(granite-moe: 40 -> 48); the router never selects padded experts.
"""
from __future__ import annotations

import inspect
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
try:                                     # jax >= 0.5 exports it at top level
    from jax import shard_map as _shard_map
except ImportError:                      # older jax: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

if "check_vma" in inspect.signature(_shard_map).parameters:
    shard_map = _shard_map
else:
    def shard_map(*args, **kw):
        """Compat shim: older jax calls the replication check ``check_rep``."""
        if "check_vma" in kw:
            kw["check_rep"] = kw.pop("check_vma")
        return _shard_map(*args, **kw)
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules
from repro.models import layers

F32 = jnp.float32


def padded_experts(cfg: ArchConfig, ep_size: Optional[int]) -> int:
    e = cfg.n_experts
    if ep_size and e % ep_size != 0:
        e = math.ceil(e / ep_size) * ep_size
    return e


def init_moe(cfg: ArchConfig, key, ep_size: Optional[int] = None):
    d, ff = cfg.d_model, cfg.moe_d_ff
    e_pad = padded_experts(cfg, ep_size)
    ks = jax.random.split(key, 4)
    p = {
        "router": layers.dense_init(ks[0], (d, cfg.n_experts), ("embed", None)),
        "w_up": layers.dense_init(ks[1], (e_pad, d, ff),
                                  ("experts", "embed", "expert_mlp"), in_axis=1),
        "w_down": layers.dense_init(ks[2], (e_pad, ff, d),
                                    ("experts", "expert_mlp", "embed"), in_axis=1),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = layers.dense_init(ks[3], (e_pad, d, ff),
                                        ("experts", "embed", "expert_mlp"), in_axis=1)
    return p


def _expert_ffn(cfg: ArchConfig, p, xg):
    """xg: (E, C, d) -> (E, C, d) through per-expert FFN."""
    dt = xg.dtype
    h = jnp.einsum("ecd,edf->ecf", xg, p["w_up"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", xg, p["w_gate"].astype(dt))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(dt))


def _route(cfg: ArchConfig, logits):
    """top-k routing. logits: (T, E_real). Returns (expert_idx (T,k), probs (T,k), aux)."""
    k = cfg.n_experts_per_tok
    probs_full = jax.nn.softmax(logits.astype(F32), axis=-1)
    top_p, top_i = lax.top_k(probs_full, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # load-balancing auxiliary loss (Switch-style): E * sum_e f_e * p_e
    E = logits.shape[-1]
    me = jnp.mean(probs_full, axis=0)                                   # (E,)
    onehot = jax.nn.one_hot(top_i, E, dtype=F32)                        # (T,k,E)
    ce = jnp.mean(jnp.sum(onehot, axis=1), axis=0)                      # fraction routed
    aux = E * jnp.sum(me * ce) / k
    return top_i, top_p.astype(logits.dtype), aux


def _group(token_e, token_w, T: int, E: int, C: int):
    """Rank tokens within their expert and build (E, C) index/weight buffers.

    token_e/token_w: (T*k,) expert id / combine weight per (token, slot).
    Returns idx (E, C) into [0, T] (T == pad sentinel), w (E, C).
    """
    Tk = token_e.shape[0]
    k = Tk // T
    onehot = jax.nn.one_hot(token_e, E, dtype=jnp.int32)                # (Tk, E)
    pos = jnp.cumsum(onehot, axis=0) - 1                                # 0-based rank
    rank = jnp.sum(pos * onehot, axis=-1)                               # (Tk,)
    keep = rank < C
    slot = jnp.where(keep, token_e * C + rank, E * C)                   # drop -> OOB
    tok_ids = jnp.arange(Tk, dtype=jnp.int32) // k                      # token of slot
    tok_of_slot = jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(tok_ids, mode="drop")
    w_of_slot = jnp.zeros((E * C + 1,), token_w.dtype).at[slot].set(token_w, mode="drop")
    return (tok_of_slot[: E * C].reshape(E, C),
            w_of_slot[: E * C].reshape(E, C))


def _moe_gather(cfg: ArchConfig, p, x, rules: ShardingRules, capacity_mult: float = 1.0):
    """Global grouped dispatch (no shard_map). x: (B, S, d)."""
    B, S, d = x.shape
    T = B * S
    xf = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xf, p["router"].astype(x.dtype))
    top_i, top_w, aux = _route(cfg, logits)
    E = p["w_up"].shape[0]                                              # padded
    k = cfg.n_experts_per_tok
    C = max(1, int(math.ceil(T * k / cfg.n_experts * cfg.capacity_factor * capacity_mult)))
    C = min(C, T)
    idx, w = _group(top_i.reshape(-1), top_w.reshape(-1), T, E, C)
    x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
    xg = x_pad[idx]                                                     # (E, C, d)
    xg = rules.constrain(xg, ("experts", None, "act_embed"))
    yg = _expert_ffn(cfg, p, xg) * w[..., None].astype(xf.dtype)
    y = jnp.zeros((T + 1, d), xf.dtype).at[idx.reshape(-1)].add(
        yg.reshape(E * C, d))[:T]
    return y.reshape(B, S, d), aux


def _moe_ep(cfg: ArchConfig, p, x, rules: ShardingRules):
    """Expert-parallel dispatch with shard_map + all_to_all over the model axis."""
    mesh = rules.mesh
    assert mesh is not None, "EP MoE requires a mesh"
    names = mesh.axis_names
    batch_axes = tuple(a for a in ("pod", "data") if a in names)
    ep_axis = "model"
    ep = dict(zip(names, mesh.devices.shape))[ep_axis]
    E = p["w_up"].shape[0]
    assert E % ep == 0, f"padded experts {E} not divisible by ep={ep}"
    E_l = E // ep
    k = cfg.n_experts_per_tok
    B, S, d = x.shape
    assert S % ep == 0, f"seq {S} not divisible by model axis {ep}"

    def local(x_l, router, w_up, w_gate, w_down):
        # x_l: (B_l, S, d) — replicated over model; take this member's seq slice.
        # w_up/w_gate/w_down arrive as the LOCAL expert slice (E_l, d, ff).
        m = lax.axis_index(ep_axis)
        B_l = x_l.shape[0]
        S_l = S // ep
        xs = lax.dynamic_slice_in_dim(x_l, m * S_l, S_l, axis=1)        # (B_l, S_l, d)
        T_l = B_l * S_l
        xf = xs.reshape(T_l, d)
        logits = jnp.einsum("td,de->te", xf, router.astype(xf.dtype))
        top_i, top_w, aux = _route(cfg, logits)
        C = max(1, int(math.ceil(T_l * k / cfg.n_experts * cfg.capacity_factor)))
        C = min(C, T_l)
        idx, w = _group(top_i.reshape(-1), top_w.reshape(-1), T_l, E, C)  # (E, C)
        x_pad = jnp.concatenate([xf, jnp.zeros((1, d), xf.dtype)], axis=0)
        xg = x_pad[idx]                                                 # (E, C, d)
        # (E, C, d) -> (ep, E_l, C, d) -> exchange -> (ep, E_l, C, d) recv
        send = xg.reshape(ep, E_l, C, d)
        recv = lax.all_to_all(send, ep_axis, split_axis=0, concat_axis=0, tiled=False)
        # recv[p]: peer-p's tokens destined for my local experts
        xr = recv.transpose(1, 0, 2, 3).reshape(E_l, ep * C, d)
        pe = {"w_up": w_up, "w_down": w_down}
        if cfg.mlp_type == "swiglu":
            pe["w_gate"] = w_gate
        yr = _expert_ffn(cfg, pe, xr)                                   # (E_l, ep*C, d)
        back = yr.reshape(E_l, ep, C, d).transpose(1, 0, 2, 3)          # (ep, E_l, C, d)
        ybuf = lax.all_to_all(back, ep_axis, split_axis=0, concat_axis=0, tiled=False)
        yg = ybuf.reshape(E, C, d) * w[..., None].astype(xf.dtype)
        y = jnp.zeros((T_l + 1, d), xf.dtype).at[idx.reshape(-1)].add(
            yg.reshape(E * C, d))[:T_l]
        y = y.reshape(B_l, S_l, d)
        # restore the full sequence on every member (SP -> replicated)
        y_full = lax.all_gather(y, ep_axis, axis=1, tiled=True)          # (B_l, S, d)
        aux = lax.pmean(aux, ep_axis)
        for a in batch_axes:
            aux = lax.pmean(aux, a)
        return y_full, aux

    bspec = P(batch_axes if len(batch_axes) != 1 else batch_axes[0], None, None)
    wspec_r = P(None, None)
    wspec_e = P(ep_axis, None, None)                                    # local experts
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(bspec, wspec_r, wspec_e,
                  wspec_e if cfg.mlp_type == "swiglu" else P(), wspec_e),
        out_specs=(bspec, P()),
        check_vma=False,
    )
    w_gate = p.get("w_gate")
    if cfg.mlp_type != "swiglu":
        w_gate = jnp.zeros((), x.dtype)
    y, aux = fn(x, p["router"], p["w_up"], w_gate, p["w_down"])
    return y, aux


def apply_moe(cfg: ArchConfig, p, x, rules: ShardingRules, impl: Optional[str] = None):
    impl = impl or cfg.moe_impl
    if impl == "ep" and rules.mesh is not None:
        return _moe_ep(cfg, p, x, rules)
    return _moe_gather(cfg, p, x, rules)
