"""Public model API: ``build_model(cfg)`` -> Model with init/loss/prefill/decode."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.distributed.sharding import ShardingRules, is_box, unbox_values
from repro.models import transformer


@dataclass
class Model:
    cfg: ArchConfig
    ep_size: Optional[int] = None

    # ---- params ----
    def init(self, key) -> Any:
        """Boxed param tree (values + logical axes)."""
        return transformer.init_lm(self.cfg, key, self.ep_size)

    def init_values(self, key) -> Any:
        return unbox_values(self.init(key))

    def abstract_params(self) -> Any:
        """Box tree of ShapeDtypeStructs (for dry-run in_shardings), fp32."""
        boxed = jax.eval_shape(lambda k: self.init(k),
                               jax.ShapeDtypeStruct((2,), jnp.uint32))
        return boxed

    # ---- steps ----
    def loss(self, params, batch, rules: ShardingRules, **kw):
        return transformer.loss_fn(self.cfg, params, batch, rules, **kw)

    def prefill(self, params, batch, rules: ShardingRules, **kw):
        return transformer.forward_prefill(self.cfg, params, batch, rules, **kw)

    def decode_step(self, params, cache, tokens, pos, rules: ShardingRules, **kw):
        return transformer.decode_step(self.cfg, params, cache, tokens, pos, rules, **kw)

    def cache_specs(self, batch: int, max_seq: int):
        return transformer.cache_specs(self.cfg, batch, max_seq)

    def init_cache(self, batch: int, max_seq: int):
        """Zero-initialized concrete cache (for serving from scratch)."""
        specs = self.cache_specs(batch, max_seq)
        return jax.tree.map(lambda b: jnp.zeros(b.value.shape, b.value.dtype),
                            specs, is_leaf=is_box)


def build_model(cfg: ArchConfig, ep_size: Optional[int] = None) -> Model:
    if cfg.moe and cfg.moe_impl == "ep" and ep_size is None:
        ep_size = 16  # production model-axis size; padded expert count depends on it
    return Model(cfg, ep_size)
