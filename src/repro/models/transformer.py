"""Unified layer-stack engine.

Every architecture is described by a *block program*: the periodic pattern of
(mixer, ffn, cross) sublayers. The stack is `n_layers = n_stack * period` deep;
parameters for each position-in-period are stacked over `n_stack` and the stack is
executed with `lax.scan` (compact HLO, fast compiles, remat per block).

  dense LMs     period=1:  [attn + dense ffn]
  MoE LMs       period=1:  [attn + moe ffn]
  mamba2 (ssm)  period=1:  [ssd]
  jamba (hybrid)period=8:  [ssd+ffn]*4 … attn at index 4, moe at odd indices
  enc-dec       two stacks: encoder [bidir attn + ffn], decoder [attn + cross + ffn]
"""
from __future__ import annotations

import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import Box, ShardingRules, is_box, unbox_values
from repro.models import layers, mamba, moe

F32 = jnp.float32


# ---------------------------------------------------------------------------
# block program
# ---------------------------------------------------------------------------

def block_period(cfg: ArchConfig) -> int:
    p = 1
    if cfg.attn_period:
        p = math.lcm(p, cfg.attn_period)
    if cfg.moe and cfg.moe_period > 1:
        p = math.lcm(p, cfg.moe_period)
    return p


def block_program(cfg: ArchConfig, decoder: bool = True) -> list[dict]:
    P = block_period(cfg)
    prog = []
    for j in range(P):
        if cfg.family == "ssm":
            mixer, ffn = "ssm", None
        elif cfg.attn_period:
            mixer = "attn" if cfg.is_attn_layer(j) else "ssm"
            ffn = "moe" if cfg.is_moe_layer(j) else ("dense" if cfg.d_ff else None)
        else:
            mixer = "attn"
            ffn = "moe" if cfg.is_moe_layer(j) else ("dense" if cfg.d_ff else None)
        prog.append({
            "mixer": mixer,
            "ffn": ffn,
            "cross": bool(cfg.encdec and decoder),
        })
    return prog


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _stack_init(init_fn, key, n: int):
    """Stack `init_fn(key)` over a leading layer dim, prefixing axes with 'stack'."""
    template = init_fn(key)
    keys = jax.random.split(key, n)
    values = jax.vmap(lambda k: unbox_values(init_fn(k)))(keys)
    return jax.tree.map(lambda b, v: Box(v, ("stack",) + b.axes),
                        template, values, is_leaf=is_box)


def _init_block_pos(cfg: ArchConfig, key, entry: dict, ep_size: Optional[int]):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"norm1": layers.init_norm(cfg, cfg.d_model)}
    if entry["mixer"] == "attn":
        p["mixer"] = layers.init_attention(cfg, ks[0])
    else:
        p["mixer"] = mamba.init_ssd(cfg, ks[0])
    if entry["cross"]:
        p["norm_cross"] = layers.init_norm(cfg, cfg.d_model)
        p["cross"] = layers.init_attention(cfg, ks[1])
    if entry["ffn"] == "dense":
        p["norm2"] = layers.init_norm(cfg, cfg.d_model)
        p["ffn"] = layers.init_mlp(cfg, ks[2])
    elif entry["ffn"] == "moe":
        p["norm2"] = layers.init_norm(cfg, cfg.d_model)
        p["ffn"] = moe.init_moe(cfg, ks[2], ep_size)
    return p


def init_lm(cfg: ArchConfig, key, ep_size: Optional[int] = None):
    prog = block_program(cfg)
    P = block_period(cfg)
    assert cfg.n_layers % P == 0, f"{cfg.n_layers} layers, period {P}"
    n_stack = cfg.n_layers // P
    k_embed, k_blocks, k_enc = jax.random.split(key, 3)
    pos_keys = jax.random.split(k_blocks, P)
    params: dict[str, Any] = {
        "embed": layers.init_embed(cfg, k_embed),
        "final_norm": layers.init_norm(cfg, cfg.d_model),
        "blocks": tuple(
            _stack_init(lambda k, j=j: _init_block_pos(cfg, k, prog[j], ep_size),
                        pos_keys[j], n_stack)
            for j in range(P)),
    }
    if cfg.encdec:
        enc_prog = block_program(cfg, decoder=False)
        assert cfg.n_enc_layers % len(enc_prog) == 0
        enc_keys = jax.random.split(k_enc, len(enc_prog))
        params["enc_blocks"] = tuple(
            _stack_init(lambda k, j=j: _init_block_pos(cfg, k, enc_prog[j], ep_size),
                        enc_keys[j], cfg.n_enc_layers // len(enc_prog))
            for j in range(len(enc_prog)))
        params["enc_norm"] = layers.init_norm(cfg, cfg.d_model)
    return params


# ---------------------------------------------------------------------------
# sublayer application
# ---------------------------------------------------------------------------

def _apply_block_pos(cfg: ArchConfig, entry: dict, p, x, rules: ShardingRules, *,
                     mode: str, positions, cache_entry=None, pos=None,
                     enc_out=None, moe_impl=None, q_chunk=1024):
    """One sublayer-group. mode: 'train' | 'prefill' | 'decode'.
    Returns (x, new_cache_entry, aux)."""
    aux = jnp.zeros((), F32)
    new_cache: dict[str, Any] = {}

    h = layers.apply_norm(cfg, p["norm1"], x)
    if entry["mixer"] == "attn":
        if mode == "decode":
            out, nc = layers.attention(cfg, p["mixer"], h, rules, mode="decode",
                                       cache=cache_entry["attn"], pos=pos)
            new_cache["attn"] = nc
        else:
            attn_mode = ("bidir" if (cfg.encdec and enc_out is None
                                     and not entry["cross"]) else "causal")
            out, nc = layers.attention(cfg, p["mixer"], h, rules, mode=attn_mode,
                                       positions=positions, q_chunk=q_chunk)
            if mode == "prefill" and nc is not None:
                new_cache["attn"] = nc
    else:
        if mode == "decode":
            out, nc = mamba.apply_ssd(cfg, p["mixer"], h, rules,
                                      cache=cache_entry["ssm"], pos=pos)
            new_cache["ssm"] = nc
        else:
            out, nc = mamba.apply_ssd(cfg, p["mixer"], h, rules,
                                      cache=({} if mode == "prefill" else None))
            if mode == "prefill":
                new_cache["ssm"] = nc
    x = x + out
    x = rules.constrain(x, ("batch", "act_seq", "act_embed"))

    if entry["cross"]:
        h = layers.apply_norm(cfg, p["norm_cross"], x)
        if mode == "decode":
            out, _ = layers.attention(cfg, p["cross"], h, rules, mode="cross_decode",
                                      cache=cache_entry["cross"])
            new_cache["cross"] = cache_entry["cross"]
        else:
            out, _ = layers.attention(cfg, p["cross"], h, rules, mode="cross",
                                      positions=positions, kv_x=enc_out)
            if mode == "prefill":
                new_cache["cross"] = layers.cross_kv(cfg, p["cross"], enc_out)
        x = x + out

    if entry["ffn"]:
        h = layers.apply_norm(cfg, p["norm2"], x)
        if entry["ffn"] == "dense":
            out = layers.apply_mlp(cfg, p["ffn"], h, rules)
        else:
            impl = moe_impl or ("gather" if mode == "decode" else cfg.moe_impl)
            out, aux = moe.apply_moe(cfg, p["ffn"], h, rules, impl=impl)
        x = x + out
        x = rules.constrain(x, ("batch", "act_seq", "act_embed"))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack execution
# ---------------------------------------------------------------------------

def _run_stack(cfg: ArchConfig, blocks, x, rules: ShardingRules, *, mode: str,
               positions, prog, cache=None, pos=None, enc_out=None,
               moe_impl=None, q_chunk=1024, remat: bool = False):
    """Scan the stacked blocks. Returns (x, new_cache_or_None, aux_sum)."""

    def body(carry, xs):
        xc = carry
        if cache is not None:
            layer_ps, cache_in = xs
        else:
            layer_ps, cache_in = xs, None
        new_caches = []
        aux_total = jnp.zeros((), F32)
        for j, entry in enumerate(prog):
            ce = cache_in[j] if cache_in is not None else None
            xc, nc, aux = _apply_block_pos(
                cfg, entry, layer_ps[j], xc, rules, mode=mode, positions=positions,
                cache_entry=ce, pos=pos, enc_out=enc_out, moe_impl=moe_impl,
                q_chunk=q_chunk)
            new_caches.append(nc)
            aux_total = aux_total + aux
        out_ys = (tuple(new_caches), aux_total) if mode != "train" else aux_total
        return xc, out_ys

    if remat:
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)

    xs = (blocks, cache) if cache is not None else blocks
    if cfg.unroll or not cfg.scan_layers:
        n_stack = jax.tree.leaves(blocks)[0].shape[0]
        ys_list = []
        for i in range(n_stack):
            x, ys_i = body(x, jax.tree.map(lambda a: a[i], xs))
            ys_list.append(ys_i)
        ys = jax.tree.map(lambda *ls: jnp.stack(ls, axis=0), *ys_list)
    else:
        x, ys = lax.scan(body, x, xs)
    if mode == "train":
        return x, None, jnp.sum(ys)
    new_cache, auxs = ys
    return x, new_cache, jnp.sum(auxs)


# ---------------------------------------------------------------------------
# public model functions
# ---------------------------------------------------------------------------

def _encode(cfg: ArchConfig, params, batch, rules: ShardingRules, q_chunk=1024,
            remat=False):
    if "frames" in batch:
        x = batch["frames"].astype(jnp.dtype(cfg.dtype))
    else:
        x = layers.embed_tokens(cfg, params["embed"], batch["src_tokens"], rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.pos_emb == "sinusoidal":
        x = x + layers.sinusoidal_pos_emb(positions, cfg.d_model, x.dtype)[None]
    enc_prog = block_program(cfg, decoder=False)
    x, _, _ = _run_stack(cfg, params["enc_blocks"], x, rules, mode="train",
                         positions=positions, prog=enc_prog, q_chunk=q_chunk,
                         remat=remat)
    return layers.apply_norm(cfg, params["enc_norm"], x)


def forward_train(cfg: ArchConfig, params, batch, rules: ShardingRules,
                  moe_impl=None, q_chunk=1024):
    """Returns (logits, aux). batch: {tokens, [frames|src_tokens]}."""
    remat = cfg.remat == "full"
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(cfg, params, batch, rules, q_chunk, remat)
    tokens = batch["tokens"]
    x = layers.embed_tokens(cfg, params["embed"], tokens, rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.pos_emb == "sinusoidal":
        x = x + layers.sinusoidal_pos_emb(positions, cfg.d_model, x.dtype)[None]
    prog = block_program(cfg)
    x, _, aux = _run_stack(cfg, params["blocks"], x, rules, mode="train",
                           positions=positions, prog=prog, enc_out=enc_out,
                           moe_impl=moe_impl, q_chunk=q_chunk, remat=remat)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, rules)
    return logits, aux


def loss_fn(cfg: ArchConfig, params, batch, rules: ShardingRules,
            moe_impl=None, q_chunk=1024, z_loss: float = 1e-4,
            moe_aux_weight: float = 1e-2):
    logits, aux = forward_train(cfg, params, batch, rules, moe_impl, q_chunk)
    targets = batch["targets"]
    if jnp.dtype(cfg.softmax_dtype) == jnp.float32:
        lf = logits.astype(F32)
        lse = jax.nn.logsumexp(lf, axis=-1)
        ll = jnp.take_along_axis(lf, targets[..., None], axis=-1)[..., 0]
    else:
        # §Perf 'bf16_loss': never materialize f32 logits — subtract the f32
        # row-max, exponentiate in bf16, accumulate the sum in f32 (reduction
        # accumulator, not a tensor), take the log in f32.
        m = jnp.max(logits, axis=-1).astype(F32)
        p = jnp.exp(logits - m[..., None].astype(logits.dtype))
        lse = m + jnp.log(jnp.sum(p, axis=-1, dtype=F32))
        ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0].astype(F32)
    nll = jnp.mean(lse - ll)
    zl = z_loss * jnp.mean(jnp.square(lse))
    total = nll + zl + moe_aux_weight * aux
    return total, {"nll": nll, "z_loss": zl, "moe_aux": aux}


def forward_prefill(cfg: ArchConfig, params, batch, rules: ShardingRules,
                    moe_impl=None, q_chunk=1024):
    """Returns (cache, last_token_logits)."""
    enc_out = None
    if cfg.encdec:
        enc_out = _encode(cfg, params, batch, rules, q_chunk)
    tokens = batch["tokens"]
    x = layers.embed_tokens(cfg, params["embed"], tokens, rules)
    S = x.shape[1]
    positions = jnp.arange(S)
    if cfg.pos_emb == "sinusoidal":
        x = x + layers.sinusoidal_pos_emb(positions, cfg.d_model, x.dtype)[None]
    prog = block_program(cfg)
    # cache entries are produced by the scan (ys): inject a dummy cache=None path
    x, new_cache, _ = _run_stack(cfg, params["blocks"], x, rules, mode="prefill",
                                 positions=positions, prog=prog, enc_out=enc_out,
                                 moe_impl=moe_impl, q_chunk=q_chunk)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x[:, -1:, :], rules)
    return new_cache, logits


def decode_step(cfg: ArchConfig, params, cache, tokens, pos, rules: ShardingRules,
                moe_impl="gather"):
    """One decode step. tokens: (B, 1); pos: scalar absolute position.
    Returns (new_cache, logits (B, 1, V))."""
    x = layers.embed_tokens(cfg, params["embed"], tokens, rules)
    if cfg.pos_emb == "sinusoidal":
        pe = layers.sinusoidal_pos_emb(jnp.asarray(pos)[None], cfg.d_model, x.dtype)
        x = x + pe[None]
    prog = block_program(cfg)
    x, new_cache, _ = _run_stack(cfg, params["blocks"], x, rules, mode="decode",
                                 positions=None, prog=prog, cache=cache, pos=pos,
                                 moe_impl=moe_impl)
    x = layers.apply_norm(cfg, params["final_norm"], x)
    logits = layers.unembed(cfg, params["embed"], x, rules)
    return new_cache, logits


# ---------------------------------------------------------------------------
# cache specs (Box tree of ShapeDtypeStructs) — must mirror scan structure
# ---------------------------------------------------------------------------

def cache_specs(cfg: ArchConfig, batch: int, max_seq: int):
    prog = block_program(cfg)
    P = block_period(cfg)
    n_stack = cfg.n_layers // P
    dt = jnp.dtype(cfg.dtype)

    def sds(shape, dtype, axes):
        return Box(jax.ShapeDtypeStruct(tuple(shape), dtype), tuple(axes))

    entries = []
    for entry in prog:
        ce: dict[str, Any] = {}
        if entry["mixer"] == "attn":
            kv_shape = (n_stack, batch, cfg.n_kv_heads, max_seq, cfg.head_dim)
            axes = ("stack", "cache_batch", "cache_heads", "cache_seq", None)
            ce["attn"] = {"k": sds(kv_shape, dt, axes), "v": sds(kv_shape, dt, axes)}
        else:
            base = mamba.cache_spec(cfg, batch)
            ce["ssm"] = jax.tree.map(
                lambda b: Box(jax.ShapeDtypeStruct((n_stack,) + b.value.shape, b.value.dtype),
                              ("stack",) + b.axes),
                base, is_leaf=is_box)
        if entry["cross"]:
            cs = (n_stack, batch, cfg.n_kv_heads, cfg.enc_memory_len, cfg.head_dim)
            axes = ("stack", "cache_batch", "cache_heads", "cache_seq", None)
            ce["cross"] = {"ck": sds(cs, dt, axes), "cv": sds(cs, dt, axes)}
        entries.append(ce)
    return tuple(entries)
