"""Model building blocks: norms, RoPE, GQA attention (train/prefill/decode/cross),
MLP variants, embeddings. Pure-functional: init returns a Box tree (value + logical
axes); apply takes the plain-value tree.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ArchConfig
from repro.distributed.sharding import Box, ShardingRules

F32 = jnp.float32


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, axes, in_axis=0, scale=1.0, dtype=F32) -> Box:
    fan_in = math.prod(shape[i] for i in range(len(shape)) if i <= in_axis)
    std = scale / math.sqrt(max(fan_in, 1))
    return Box(jax.random.normal(key, shape, dtype) * std, tuple(axes))


def zeros_init(shape, axes, dtype=F32) -> Box:
    return Box(jnp.zeros(shape, dtype), tuple(axes))


def ones_init(shape, axes, dtype=F32) -> Box:
    return Box(jnp.ones(shape, dtype), tuple(axes))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ArchConfig, dim: int, axes=("act_embed",)):
    p = {"scale": ones_init((dim,), axes)}
    if cfg.norm == "layernorm":
        p["bias"] = zeros_init((dim,), axes)
    return p


def apply_norm(cfg: ArchConfig, p, x, eps: float = 1e-5):
    xf = x.astype(F32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * lax.rsqrt(var + eps)
        y = y * p["scale"].astype(F32) + p["bias"].astype(F32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * lax.rsqrt(ms + eps) * p["scale"].astype(F32)
    return y.astype(x.dtype)


def rms_norm_nohead(x, scale, eps=1e-6):
    """RMS norm over the last dim (used for qk-norm and SSD gated norm)."""
    xf = x.astype(F32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(ms + eps) * scale.astype(F32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (supports partial rotary — chatglm3 "RoPE 2d" == fraction 0.5)
# ---------------------------------------------------------------------------

def apply_rope(x, positions, theta: float, fraction: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * fraction)
    rot -= rot % 2
    if rot == 0:
        return x
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    half = rot // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=F32) * (math.log(theta) / half))
    ang = positions[..., None].astype(F32) * freqs          # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]                        # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x_rot[..., :half].astype(F32), x_rot[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


def sinusoidal_pos_emb(positions, dim: int, dtype):
    half = dim // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=F32) * (math.log(10_000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(F32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def init_attention(cfg: ArchConfig, key):
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, H, hd), ("embed", "heads", "head_dim")),
        "wk": dense_init(ks[1], (d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wv": dense_init(ks[2], (d, K, hd), ("embed", "kv_heads", "head_dim")),
        "wo": dense_init(ks[3], (H, hd, d), ("heads", "head_dim", "embed"), in_axis=1),
    }
    if cfg.qk_norm:
        p["q_norm"] = ones_init((hd,), ("head_dim",))
        p["k_norm"] = ones_init((hd,), ("head_dim",))
    return p


def _qkv(cfg: ArchConfig, p, x, kv_x, positions, kv_positions, rope: bool):
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(dt))
    if cfg.qk_norm:
        q = rms_norm_nohead(q, p["q_norm"])
        k = rms_norm_nohead(k, p["k_norm"])
    if rope and cfg.pos_emb == "rope":
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, kv_positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def _sdpa(cfg: ArchConfig, q, k, v, *, causal: bool, q_offset=0, kv_valid_len=None,
          q_chunk: int = 1024, rules: Optional[ShardingRules] = None,
          layout: str = "heads"):
    """Grouped-query scaled-dot-product attention, query-chunked to bound the
    live score buffer. fp32 softmax.

    KV heads are expanded to the full head count BEFORE the einsums so scores
    stay head-sharded under TP even when n_kv_heads < TP size (GQA/MQA): the
    grouped (B, K, G, ...) layout defeats GSPMD sharding propagation and
    replicates the score tensor (verified 16x HBM-traffic regression).

    q: (B, Sq, H, hd);  k/v: (B, Skv, K, hd).
    q_offset: absolute position of q[0] (for causal masking in prefill chunks).
    kv_valid_len: mask kv positions >= this (decode with pre-allocated cache).
    """
    B, Sq, H, hd = q.shape
    scale = 1.0 / math.sqrt(hd)

    if layout == "seq":
        # Decode layout: k/v are (B, K, Skv, hd), SEQ-sharded (the KV cache
        # layout); scores inherit the seq sharding and GSPMD turns the softmax +
        # PV contraction into a two-pass partial reduction (tiny collectives)
        # instead of re-sharding the whole cache to heads (full-cache
        # all-gather, verified 20x step-time regression).
        K = k.shape[1]
        G = H // K
        Skv = k.shape[2]
        kv_pos = jnp.arange(Skv)
        qg = q.reshape(B, Sq, K, G, hd)
        s = jnp.einsum("bqkgh,bksh->bkgqs", qg, k).astype(F32) * scale
        if rules is not None:
            s = rules.constrain(s, ("batch", None, None, None, "cache_seq"))
        if kv_valid_len is not None:
            s = jnp.where((kv_pos < kv_valid_len)[None, None, None, None, :],
                          s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        o = jnp.einsum("bkgqs,bksh->bqkgh", w, v)
        return o.reshape(B, Sq, H, hd)

    K = k.shape[2]
    # TP layout: shard attention over heads when H divides the model axis;
    # otherwise fall back to SEQUENCE parallelism on the q dimension (minitron /
    # granite-moe have H=24 vs TP=16 — head sharding would silently replicate
    # the score tensors 16x).
    tp = 1
    if rules is not None and rules.mesh is not None:
        tp = dict(zip(rules.mesh.axis_names,
                      rules.mesh.devices.shape)).get("model", 1)
    sp = tp > 1 and (H % tp != 0)
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)       # (B, Skv, H, hd), head-shardable
        v = jnp.repeat(v, H // K, axis=2)
    kv_axes = ("batch", "act_seq", None if sp else "act_heads", None)
    if rules is not None:
        k = rules.constrain(k, kv_axes)
        v = rules.constrain(v, kv_axes)
    Skv = k.shape[1]
    kv_pos = jnp.arange(Skv)

    def chunk_attn(qc, row0, kc, vc, kvp):
        Qc = qc.shape[1]
        if rules is not None and sp:
            qc = rules.constrain(qc, ("batch", "sp_seq", None, None))
        s = jnp.einsum("bqhd,bkhd->bhqk", qc, kc).astype(F32) * scale
        if rules is not None:
            s = rules.constrain(s, ("batch", None, "sp_seq", None) if sp
                                else ("batch", "act_heads", None, None))
        mask = None
        if causal:
            rows = row0 + jnp.arange(Qc)
            mask = kvp[None, :] <= rows[:, None]
        if kv_valid_len is not None:
            vm = (kvp < kv_valid_len)[None, :]
            mask = vm if mask is None else (mask & vm)
        if mask is not None:
            s = jnp.where(mask[None, None], s, -1e30)
        w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", w, vc)

    if Sq <= q_chunk:
        return chunk_attn(q, q_offset, k, v, kv_pos)
    n = Sq // q_chunk
    assert Sq % q_chunk == 0, f"seq {Sq} not divisible by q_chunk {q_chunk}"
    qs = q.reshape(B, n, q_chunk, H, hd).swapaxes(0, 1)     # (n, B, Qc, H, hd)

    def run_chunks(qs_n, row0, kc, vc, kvp):
        """Scan (or unroll) chunk_attn over a block of q chunks with fixed kv."""
        if cfg.unroll:
            outs = [chunk_attn(qs_n[i], row0 + i * q_chunk, kc, vc, kvp)
                    for i in range(qs_n.shape[0])]
            out = jnp.stack(outs, axis=0)
        else:
            def body(_, qc_i):
                qc, i = qc_i
                return None, chunk_attn(qc, row0 + i * q_chunk, kc, vc, kvp)
            _, out = lax.scan(body, None, (qs_n, jnp.arange(qs_n.shape[0])))
        return out

    if causal and cfg.causal_block_skip and q_offset == 0 and kv_valid_len is None:
        # bucketed block-causal: bucket b's q chunks read only kv[0:(b+1)*S/nb]
        # (static slices; scan within a bucket keeps one chunk live). nb=8
        # buckets skip ~44% of the full rectangle's flops+bytes.
        nb = min(8, n)
        while n % nb:
            nb -= 1
        per = n // nb
        outs = []
        for b in range(nb):
            hi = (b + 1) * per * q_chunk
            out_b = run_chunks(qs[b * per:(b + 1) * per], b * per * q_chunk,
                               k[:, :hi], v[:, :hi], kv_pos[:hi])
            outs.append(out_b)
        out = jnp.concatenate(outs, axis=0)
        return out.swapaxes(0, 1).reshape(B, Sq, H, hd)

    out = run_chunks(qs, q_offset, k, v, kv_pos)
    return out.swapaxes(0, 1).reshape(B, Sq, H, hd)


def attention(cfg: ArchConfig, p, x, rules: ShardingRules, *, mode: str,
              positions=None, cache=None, pos=None, kv_x=None, q_chunk: int = 1024):
    """mode: 'causal' | 'bidir' (encoder) | 'cross' | 'decode' | 'cross_decode'.

    decode: cache = {'k': (B, Smax, K, hd), 'v': ...}, pos = scalar position.
    cross_decode: cache holds fixed projected cross k/v.
    Returns (out, new_cache_or_None).
    """
    dt = x.dtype
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim

    if mode in ("causal", "bidir", "cross"):
        kvx = x if kv_x is None else kv_x
        kv_positions = positions if kv_x is None else jnp.arange(kvx.shape[1])
        q, k, v = _qkv(cfg, p, x, kvx, positions, kv_positions, rope=(mode != "cross"))
        q = rules.constrain(q, ("batch", "act_seq", "act_heads", None))
        k = rules.constrain(k, ("batch", "act_seq", "act_heads", None))
        out = _sdpa(cfg, q, k, v, causal=(mode == "causal"), q_chunk=q_chunk,
                    rules=rules)
        new_cache = None
        if mode == "causal":
            # prefill cache layout: (B, K, S, hd) — seq minor-adjacent so the
            # decode contractions need no transposed copies
            new_cache = {"k": k.swapaxes(1, 2), "v": v.swapaxes(1, 2)}
        out = rules.constrain(out, ("batch", "act_seq", "act_heads", None))
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return o, new_cache

    if mode == "decode":
        posv = jnp.asarray(pos)
        q, k, v = _qkv(cfg, p, x, x, posv[None, None], posv[None, None], rope=True)
        kt = k.swapaxes(1, 2).astype(cache["k"].dtype)      # (B, K, 1, hd)
        vt = v.swapaxes(1, 2).astype(cache["v"].dtype)
        ck = lax.dynamic_update_slice(cache["k"], kt, (0, 0, posv, 0))
        cv = lax.dynamic_update_slice(cache["v"], vt, (0, 0, posv, 0))
        ck = rules.constrain(ck, ("cache_batch", "cache_heads", "cache_seq", None))
        cv = rules.constrain(cv, ("cache_batch", "cache_heads", "cache_seq", None))
        out = _sdpa(cfg, q, ck.astype(dt), cv.astype(dt), causal=False,
                    kv_valid_len=posv + 1, rules=rules, layout="seq")
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return o, {"k": ck, "v": cv}

    if mode == "cross_decode":
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
        if cfg.qk_norm:
            q = rms_norm_nohead(q, p["q_norm"])
        out = _sdpa(cfg, q, cache["ck"].astype(dt), cache["cv"].astype(dt),
                    causal=False, rules=rules, layout="seq")
        o = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
        return o, cache

    raise ValueError(f"unknown attention mode {mode!r}")


def cross_kv(cfg: ArchConfig, p, enc_out):
    """Project encoder output once into cross-attention K/V (decode setup).
    Layout (B, K, S, hd), matching the self-attention cache."""
    dt = enc_out.dtype
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
    return {"ck": k.swapaxes(1, 2), "cv": v.swapaxes(1, 2)}


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg: ArchConfig, key, d_ff: Optional[int] = None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_up": dense_init(ks[0], (d, ff), ("embed", "mlp")),
        "w_down": dense_init(ks[1], (ff, d), ("mlp", "embed")),
    }
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = dense_init(ks[2], (d, ff), ("embed", "mlp"))
    return p


def apply_mlp(cfg: ArchConfig, p, x, rules: ShardingRules):
    dt = x.dtype
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(dt))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(dt))
        h = jax.nn.silu(g) * h
    elif cfg.mlp_type == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    h = rules.constrain(h, ("batch", "act_seq", "act_mlp"))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# embeddings
# ---------------------------------------------------------------------------

def init_embed(cfg: ArchConfig, key):
    ks = jax.random.split(key, 2)
    p = {"tok": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                           ("vocab", "embed"), scale=1.0)}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(ks[1], (cfg.d_model, cfg.vocab_size),
                                  ("embed", "vocab"))
    return p


def embed_tokens(cfg: ArchConfig, p, tokens, rules: ShardingRules):
    x = jnp.take(p["tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return rules.constrain(x, ("batch", "act_seq", "act_embed"))


def unembed(cfg: ArchConfig, p, x, rules: ShardingRules):
    dt = x.dtype
    w = p["tok"].T if cfg.tie_embeddings else p["unembed"]
    logits = jnp.einsum("bsd,dv->bsv", x, w.astype(dt))
    return rules.constrain(logits, ("batch", "act_seq", "act_vocab"))
