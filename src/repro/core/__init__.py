# ContainerStress — the paper's primary contribution: autonomous cloud-node
# scoping via nested-loop Monte Carlo + compile-time roofline analysis.
from repro.core.catalog import CATALOG, CloudShape, get_shape, register_shape
from repro.core.cost_model import (HardwareSpec, RooflineTerms, V5E, dollar_cost,
                                   mfu, roofline)
from repro.core.hlo_analysis import CompiledCost, analyze_compiled, parse_collectives
from repro.core.recommender import (Constraint, Recommendation,
                                    elasticity_plan, feasible_ranking,
                                    recommend)
from repro.core.scoping import CellResult, ContainerStress, ScopingResult
from repro.core.surfaces import (ResponseSurface, fit_response_surface,
                                 grid_to_matrix, render_ascii_surface)

__all__ = [
    "CATALOG", "CloudShape", "get_shape", "register_shape", "HardwareSpec",
    "RooflineTerms", "V5E",
    "dollar_cost", "mfu", "roofline", "CompiledCost", "analyze_compiled",
    "parse_collectives", "Constraint", "Recommendation", "elasticity_plan",
    "feasible_ranking", "recommend", "CellResult", "ContainerStress", "ScopingResult",
    "ResponseSurface", "fit_response_surface", "grid_to_matrix",
    "render_ascii_surface",
]
