"""Extract roofline inputs from a compiled XLA executable.

``cost_analysis()`` gives per-device FLOPs / bytes-accessed; collective traffic is
NOT in cost_analysis, so we parse the post-SPMD HLO text and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# collective op line: "%name = <shapes> <kind>(" or "ROOT %name = ..."
_COLL_LINE_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[\w\[\]{},\s]*?)\s*"
    r"(?P<kind>all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)\(")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_kind: dict = field(default_factory=dict)
    count_by_kind: dict = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device operand/result bytes of collective ops in post-SPMD HLO."""
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m:
            continue
        kind = m.group("kind").replace("-start", "")
        nbytes = _shape_bytes(m.group("shapes"))
        stats.bytes_by_kind[kind] = stats.bytes_by_kind.get(kind, 0) + nbytes
        stats.count_by_kind[kind] = stats.count_by_kind.get(kind, 0) + 1
    return stats


@dataclass
class CompiledCost:
    """Everything the roofline needs, in GLOBAL units (per-device x n_devices)."""
    n_devices: int
    flops: float                 # global FLOPs per step
    bytes_accessed: float        # global HBM traffic per step
    collective_bytes: float      # global collective traffic per step
    collectives: CollectiveStats
    peak_memory_per_device: float
    argument_bytes_per_device: float
    temp_bytes_per_device: float
    output_bytes_per_device: float

    def as_dict(self) -> dict:
        return {
            "n_devices": self.n_devices,
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_bytes_by_kind": dict(self.collectives.bytes_by_kind),
            "collective_count_by_kind": dict(self.collectives.count_by_kind),
            "peak_memory_per_device": self.peak_memory_per_device,
            "argument_bytes_per_device": self.argument_bytes_per_device,
            "temp_bytes_per_device": self.temp_bytes_per_device,
            "output_bytes_per_device": self.output_bytes_per_device,
        }


def cost_analysis_dict(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions: older releases
    return a one-element list of dicts, newer ones a plain dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def analyze_compiled(compiled, n_devices: int = 1,
                     hlo_text: Optional[str] = None) -> CompiledCost:
    """cost_analysis()/memory_analysis() report PER-DEVICE numbers for SPMD
    executables; pass n_devices to globalize."""
    ca = cost_analysis_dict(compiled)
    ma = compiled.memory_analysis()
    text = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(text)

    flops_dev = float(ca.get("flops", 0.0))
    bytes_dev = float(ca.get("bytes accessed", 0.0))
    arg_b = float(getattr(ma, "argument_size_in_bytes", 0.0))
    tmp_b = float(getattr(ma, "temp_size_in_bytes", 0.0))
    out_b = float(getattr(ma, "output_size_in_bytes", 0.0))
    return CompiledCost(
        n_devices=n_devices,
        flops=flops_dev * n_devices,
        bytes_accessed=bytes_dev * n_devices,
        collective_bytes=float(colls.total_bytes) * n_devices,
        collectives=colls,
        peak_memory_per_device=arg_b + tmp_b + out_b,
        argument_bytes_per_device=arg_b,
        temp_bytes_per_device=tmp_b,
        output_bytes_per_device=out_b,
    )
