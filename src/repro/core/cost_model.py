"""Roofline + dollar cost model (the 'compute cost' axis of the paper's response
surfaces, priced for TPU v5e shapes instead of CPU/GPU VM shapes).

Constants per the brief: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class HardwareSpec:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12          # bf16 FLOP/s per chip
    hbm_bw: float = 819e9               # B/s per chip
    ici_bw: float = 50e9                # B/s per ICI link
    hbm_per_chip: float = 16 * 2**30    # bytes
    price_per_chip_hour: float = 1.20   # USD (public on-demand v5e)


V5E = HardwareSpec()


@dataclass
class RooflineTerms:
    """All terms in seconds-per-step for the whole job (global work / aggregate
    capability)."""
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_step(self) -> float:
        """Ideal-overlap step time (the roofline bound)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def t_serial(self) -> float:
        """No-overlap upper bound."""
        return self.t_compute + self.t_memory + self.t_collective

    def as_dict(self) -> dict:
        return {"t_compute": self.t_compute, "t_memory": self.t_memory,
                "t_collective": self.t_collective, "t_step": self.t_step,
                "dominant": self.dominant}


def roofline(flops_global: float, bytes_global: float, coll_bytes_global: float,
             chips: int, hw: HardwareSpec = V5E) -> RooflineTerms:
    return RooflineTerms(
        t_compute=flops_global / (chips * hw.peak_flops),
        t_memory=bytes_global / (chips * hw.hbm_bw),
        t_collective=coll_bytes_global / (chips * hw.ici_bw),
    )


def dollar_cost(step_time_s: float, n_steps: float, chips: int,
                hw: HardwareSpec = V5E) -> float:
    hours = step_time_s * n_steps / 3600.0
    return hours * chips * hw.price_per_chip_hour


def mfu(model_flops: float, step_time_s: float, chips: int,
        hw: HardwareSpec = V5E) -> float:
    """Model FLOPs utilization against aggregate peak."""
    if step_time_s <= 0:
        return 0.0
    return model_flops / (step_time_s * chips * hw.peak_flops)
