"""The cloud-'Shape' catalog (paper: CPU/GPU container shapes -> TPU v5e slices).

Each shape is a mesh the scoping engine can compile against; multi-pod shapes add
the ``pod`` axis crossed by DCI.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax

from repro.core.cost_model import V5E, HardwareSpec


@dataclass(frozen=True)
class CloudShape:
    name: str
    mesh_shape: tuple
    axes: tuple
    hw: HardwareSpec = V5E

    @property
    def chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n

    @property
    def price_per_hour(self) -> float:
        return self.chips * self.hw.price_per_chip_hour

    def make_mesh(self):
        return jax.make_mesh(self.mesh_shape, self.axes)


CATALOG: list[CloudShape] = [
    CloudShape("v5e-4", (2, 2), ("data", "model")),
    CloudShape("v5e-8", (2, 4), ("data", "model")),
    CloudShape("v5e-16", (4, 4), ("data", "model")),
    CloudShape("v5e-32", (4, 8), ("data", "model")),
    CloudShape("v5e-64", (8, 8), ("data", "model")),
    CloudShape("v5e-128", (8, 16), ("data", "model")),
    CloudShape("v5e-256", (16, 16), ("data", "model")),
    CloudShape("2x-v5e-256", (2, 16, 16), ("pod", "data", "model")),
]

_BY_NAME: dict[str, CloudShape] = {s.name: s for s in CATALOG}


def get_shape(name: str) -> CloudShape:
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown cloud shape {name!r}; known: "
                       f"{[s.name for s in CATALOG]}") from None


def register_shape(shape: CloudShape, overwrite: bool = False) -> CloudShape:
    """Add a custom shape to the catalog (e.g. fleet scenarios injecting
    non-standard slices or alternate HardwareSpecs)."""
    if shape.name in _BY_NAME and not overwrite:
        raise ValueError(f"shape {shape.name!r} already registered "
                         "(pass overwrite=True to replace)")
    if shape.name in _BY_NAME:
        CATALOG[[s.name for s in CATALOG].index(shape.name)] = shape
    else:
        CATALOG.append(shape)
    _BY_NAME[shape.name] = shape
    return shape
