"""Autonomous container recommendation (the paper's end goal: 'scope out the
cloud containers that would be the most appropriate reference for any prospective
use case').

Given analytic scoping rows (per-shape roofline costs) and a customer constraint,
pick the cheapest feasible CloudShape and produce an elasticity growth plan.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional

from repro.core.catalog import CloudShape, get_shape


@dataclass(frozen=True)
class Constraint:
    max_step_latency_s: Optional[float] = None     # real-time surveillance bound
    min_throughput_per_s: Optional[float] = None   # units (tokens/observations)/s
    max_usd_per_hour: Optional[float] = None
    units_per_step: float = 1.0                    # for throughput conversion

    def feasible(self, t_step: float, shape: CloudShape,
                 hbm_used: Optional[float] = None) -> bool:
        if not (t_step > 0.0 and math.isfinite(t_step)):
            return False    # zero/negative/NaN step time = untrustworthy probe
        if self.max_step_latency_s is not None and t_step > self.max_step_latency_s:
            return False
        if (self.min_throughput_per_s is not None
                and self.units_per_step / max(t_step, 1e-12) < self.min_throughput_per_s):
            return False
        if (self.max_usd_per_hour is not None
                and shape.price_per_hour > self.max_usd_per_hour):
            return False
        if hbm_used is not None and hbm_used > shape.hw.hbm_per_chip:
            return False
        return True


@dataclass
class Recommendation:
    shape: Optional[CloudShape]
    t_step: Optional[float]
    usd_per_hour: Optional[float]
    ranking: list                      # [(shape_name, t_step, $/hr, feasible)]
    reason: str = ""


def _rank_key(entry):
    # (price, t_step, chips, name): a total order even when two shapes tie on
    # price AND step time — frozen CloudShape itself is unorderable, so a bare
    # tuple sort would raise TypeError on duplicate-cost rows.
    price, t, shape = entry
    return (price, t, shape.chips, shape.name)


def feasible_ranking(rows, constraint: Constraint) -> list:
    """Feasible ``(price_per_hour, t_step, CloudShape)`` rows, cheapest first.

    This is the ordering ``recommend()`` picks from; heterogeneous fleet
    policies reuse it to split pools into baseline (head of the ranking) and
    burst capacity (the rest)."""
    feasible = []
    for r in rows:
        shape = get_shape(r.shape_name)
        t = r.terms.t_step
        hbm = (r.analysis or {}).get("peak_memory_per_device")
        if constraint.feasible(t, shape, hbm):
            feasible.append((shape.price_per_hour, t, shape))
    feasible.sort(key=_rank_key)
    return feasible


def recommend(rows, constraint: Constraint) -> Recommendation:
    """rows: CellResult list from ContainerStress.run_analytic for ONE use case
    across multiple shapes."""
    ranking = []
    for r in rows:
        shape = get_shape(r.shape_name)
        t = r.terms.t_step
        hbm = (r.analysis or {}).get("peak_memory_per_device")
        ok = constraint.feasible(t, shape, hbm)
        ranking.append((shape.name, t, shape.price_per_hour, ok))
    ranking.sort(key=lambda x: x[2])
    feasible = feasible_ranking(rows, constraint)
    if not feasible:
        return Recommendation(None, None, None, ranking,
                              reason="no catalog shape satisfies the constraint")
    price, t, shape = feasible[0]
    return Recommendation(shape, t, price, ranking,
                          reason=f"cheapest feasible shape ({shape.chips} chips)")


def elasticity_plan(surface, shapes: list, growth_param: str, values: list,
                    base_params: dict, constraint: Constraint) -> list:
    """Growth plan: for each value of the growing parameter (e.g. n_signals as a
    customer instruments more sensors), the cheapest feasible shape predicted by
    the response surface (per-shape surfaces fitted upstream).

    surface: dict shape_name -> ResponseSurface fitted on (params -> t_step).
    Returns [(value, shape_name, predicted_t_step)].
    """
    plan = []
    for v in values:
        params = dict(base_params, **{growth_param: v})
        best = None
        for s in shapes:
            t = surface[s.name].predict(params)
            if constraint.feasible(t, s):
                if best is None or s.price_per_hour < best[2]:
                    best = (s.name, t, s.price_per_hour)
        plan.append((v, best[0] if best else None, best[1] if best else None))
    return plan
