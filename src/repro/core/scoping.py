"""ContainerStress — the paper's autonomous scoping engine.

Nested-loop Monte Carlo simulation over the ML design parameters (paper Fig. 1):
for every grid cell, the workload is instantiated and its compute cost measured;
results feed the response surfaces (surfaces.py) and the recommender.

Two cost probes:

* ``run_measured``  — wall-clock of the jitted workload on the current backend,
  repeated over Monte Carlo draws (TPSS-synthesized inputs). This is the paper's
  own methodology (it timed CPU/GPU containers).
* ``run_analytic``  — TPU-target extension: lower + compile the workload for a
  catalog CloudShape and derive the three-term roofline cost from the compiled
  artifact (no hardware needed). This is what lets one dev box scope 512-chip
  configurations.
"""
from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, Optional

import jax
import numpy as np

from repro.core.catalog import CloudShape
from repro.core.cost_model import HardwareSpec, RooflineTerms, V5E, dollar_cost, roofline
from repro.core.hlo_analysis import analyze_compiled


@dataclass
class CellResult:
    params: dict
    mean_s: float = float("nan")          # measured seconds per call
    std_s: float = float("nan")
    reps: int = 0
    shape_name: Optional[str] = None
    terms: Optional[RooflineTerms] = None
    analysis: Optional[dict] = None
    usd_per_1k_steps: Optional[float] = None

    def cost(self) -> float:
        """Scalar compute cost for surface fitting (seconds)."""
        if self.terms is not None:
            return self.terms.t_step
        return self.mean_s

    def service_terms(self, units_per_step: float = 1.0) -> tuple:
        """Split this cell's per-step cost into ``(t_fixed, t_per_unit)`` seconds
        for queueing models: serving a batch of b units takes
        ``t_fixed + b * t_per_unit``.

        With roofline terms, weight-streaming (memory) and collective traffic are
        batch-independent while compute scales with the batch; measured cells have
        no decomposition, so the whole cost amortizes linearly.
        """
        if units_per_step <= 0:
            raise ValueError(f"units_per_step must be positive, got {units_per_step}")
        if self.terms is not None:
            t_fixed = max(self.terms.t_memory, self.terms.t_collective)
            return t_fixed, self.terms.t_compute / units_per_step
        return 0.0, self.mean_s / units_per_step


@dataclass
class ScopingResult:
    rows: list = field(default_factory=list)

    def param_names(self) -> list:
        return list(self.rows[0].params) if self.rows else []

    def to_arrays(self):
        names = self.param_names()
        X = np.array([[r.params[n] for n in names] for r in self.rows], float)
        y = np.array([r.cost() for r in self.rows], float)
        return names, X, y


def _grid(grid: dict[str, Iterable]) -> list[dict]:
    names = list(grid)
    return [dict(zip(names, vals)) for vals in itertools.product(*grid.values())]


class ContainerStress:
    """workload_fn(params: dict) must return a zero-arg callable that executes one
    unit of work (already jitted; inputs baked in / regenerated via MC draws), or
    — for analytic mode — (jitted_fn, example_args: tuple) to lower+compile.
    """

    def __init__(self, hw: HardwareSpec = V5E):
        self.hw = hw

    # ------------------------- measured (paper-faithful) -------------------
    def run_measured(self, workload_fn: Callable[[dict], Callable[[], Any]],
                     grid: dict[str, Iterable], reps: int = 3,
                     constraint: Optional[Callable[[dict], bool]] = None,
                     verbose: bool = False) -> ScopingResult:
        res = ScopingResult()
        for params in _grid(grid):
            if constraint and not constraint(params):
                continue
            try:
                run = workload_fn(params)
            except Exception as e:  # infeasible cell (e.g. OOM) — record & move on
                if verbose:
                    print(f"[containerstress] skip {params}: {e}")
                continue
            run()  # warmup / compile
            ts = []
            for _ in range(reps):
                t0 = time.perf_counter()
                out = run()
                jax.block_until_ready(out)
                ts.append(time.perf_counter() - t0)
            r = CellResult(params=params, mean_s=float(np.mean(ts)),
                           std_s=float(np.std(ts)), reps=reps)
            res.rows.append(r)
            if verbose:
                print(f"[containerstress] {params} -> {r.mean_s*1e3:.2f} ms "
                      f"(±{r.std_s*1e3:.2f})")
        return res

    # ------------------------- analytic (TPU dry-run) ----------------------
    def run_analytic(self, lower_fn: Callable[[dict, CloudShape], Any],
                     grid: dict[str, Iterable], shapes: list[CloudShape],
                     n_steps_for_cost: float = 1000.0,
                     constraint: Optional[Callable[[dict], bool]] = None,
                     verbose: bool = False) -> ScopingResult:
        """lower_fn(params, shape) -> jax.stages.Lowered for that mesh."""
        res = ScopingResult()
        for params in _grid(grid):
            if constraint and not constraint(params):
                continue
            for shape in shapes:
                try:
                    lowered = lower_fn(params, shape)
                    compiled = lowered.compile()
                except Exception as e:
                    if verbose:
                        print(f"[containerstress] {shape.name} {params} failed: {e}")
                    continue
                cost = analyze_compiled(compiled, n_devices=shape.chips)
                terms = roofline(cost.flops, cost.bytes_accessed,
                                 cost.collective_bytes, shape.chips, self.hw)
                usd = dollar_cost(terms.t_step, n_steps_for_cost, shape.chips, self.hw)
                r = CellResult(params=dict(params, shape=shape.chips),
                               shape_name=shape.name, terms=terms,
                               analysis=cost.as_dict(), usd_per_1k_steps=usd)
                res.rows.append(r)
                if verbose:
                    print(f"[containerstress] {shape.name} {params}: "
                          f"t_step={terms.t_step*1e3:.3f} ms dom={terms.dominant} "
                          f"${usd:.2f}/1k steps")
        return res
