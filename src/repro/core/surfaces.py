"""3-D response-surface methodology (paper Figs. 4-8): fit compute cost as a
parametric function of the ML design parameters, in log-log space (costs scale
polynomially, so log-log quadratic captures them well), and render ASCII contour
surfaces for terminal reports.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class ResponseSurface:
    """Fitted log-log polynomial surface.

    ``box_lo``/``box_hi`` (log-space, per dim) bound the sample the fit saw.
    A quadratic extrapolated outside its design region grows without bound —
    silently returning those values poisons anything downstream (a tuner
    chasing a fictitious minimum, an oracle interpolating a fantasy cost).
    Queries outside the box are clamped to its hull and flag
    ``extrapolated`` instead; surfaces built without a box (hand-constructed)
    keep the old unclamped behaviour.
    """
    names: list
    coef: np.ndarray
    r2: float
    degree: int
    box_lo: np.ndarray = None       # (k,) log-space fitted sample min
    box_hi: np.ndarray = None       # (k,) log-space fitted sample max
    extrapolated: bool = False      # last predict* clamped at least one query

    def _clamp(self, L: np.ndarray) -> np.ndarray:
        if self.box_lo is None or self.box_hi is None:
            self.extrapolated = False
            return L
        C = np.clip(L, self.box_lo, self.box_hi)
        self.extrapolated = bool(np.any(C != L))
        return C

    def predict(self, params: dict) -> float:
        x = np.array([[float(params[n]) for n in self.names]])
        L = self._clamp(np.log(x))
        return float(np.exp(_design(L, self.degree) @ self.coef)[0])

    def predict_many(self, X: np.ndarray) -> np.ndarray:
        L = self._clamp(np.log(np.asarray(X, float)))
        return np.exp(_design(L, self.degree) @ self.coef)

    def to_json(self) -> dict:
        return {
            "names": list(self.names),
            "coef": [float(c) for c in np.asarray(self.coef).ravel()],
            "r2": float(self.r2),
            "degree": int(self.degree),
            "box_lo": (None if self.box_lo is None
                       else [float(v) for v in self.box_lo]),
            "box_hi": (None if self.box_hi is None
                       else [float(v) for v in self.box_hi]),
        }

    @staticmethod
    def from_json(d: dict) -> "ResponseSurface":
        return ResponseSurface(
            names=list(d["names"]), coef=np.asarray(d["coef"], float),
            r2=float(d["r2"]), degree=int(d["degree"]),
            box_lo=(None if d.get("box_lo") is None
                    else np.asarray(d["box_lo"], float)),
            box_hi=(None if d.get("box_hi") is None
                    else np.asarray(d["box_hi"], float)))


def _design(L: np.ndarray, degree: int) -> np.ndarray:
    """Design matrix for log-space polynomial: 1 + linear + (quadratic+cross)."""
    cols = [np.ones(len(L))]
    k = L.shape[1]
    cols += [L[:, i] for i in range(k)]
    if degree >= 2:
        for i in range(k):
            for j in range(i, k):
                cols.append(L[:, i] * L[:, j])
    return np.stack(cols, axis=1)


def _n_cols(k: int, degree: int) -> int:
    return 1 + k + (k * (k + 1) // 2 if degree >= 2 else 0)


def fit_response_surface(names, X, y, degree: int = 2) -> ResponseSurface:
    """X: (n, k) raw params; y: (n,) positive costs.

    A fit with fewer usable points than design-matrix columns is
    underdetermined — lstsq would happily return one of infinitely many
    interpolants (r2 == 1, garbage everywhere off the data). Rather than hand
    back a surface nothing downstream can trust, degrade to ``degree=1`` when
    the quadratic is underdetermined, and raise when even the linear fit is.
    """
    X = np.asarray(X, float)
    y = np.asarray(y, float)
    keep = (y > 0) & np.all(X > 0, axis=1)
    L, ly = np.log(X[keep]), np.log(y[keep])
    k = L.shape[1]
    while degree > 1 and len(ly) < _n_cols(k, degree):
        degree -= 1
    if len(ly) < _n_cols(k, degree):
        raise ValueError(
            f"fit_response_surface: {len(ly)} usable point(s) cannot "
            f"determine even a degree-1 surface in {k} dim(s) "
            f"(need >= {_n_cols(k, 1)}); widen the design or drop dims")
    A = _design(L, degree)
    coef, *_ = np.linalg.lstsq(A, ly, rcond=None)
    pred = A @ coef
    ss_res = float(np.sum((ly - pred) ** 2))
    ss_tot = float(np.sum((ly - ly.mean()) ** 2)) or 1.0
    return ResponseSurface(list(names), coef, 1.0 - ss_res / ss_tot, degree,
                           box_lo=L.min(axis=0), box_hi=L.max(axis=0))


_RAMP = " .:-=+*#%@"


def render_ascii_surface(xs, ys, Z, x_name: str = "x", y_name: str = "y",
                         title: str = "") -> str:
    """Z[i, j] = cost at (ys[i], xs[j]). Log-scaled density ramp, blue->red in the
    paper; here ' ' (cheap) -> '@' (expensive)."""
    Z = np.asarray(Z, float)
    lz = np.log(np.where(Z > 0, Z, np.nan))
    lo, hi = np.nanmin(lz), np.nanmax(lz)
    span = (hi - lo) or 1.0
    lines = []
    if title:
        lines.append(title)
    lines.append(f"rows: {y_name} (bottom=min) / cols: {x_name} (left=min)  "
                 f"ramp '{_RAMP}' = log cost min->max")
    for i in range(Z.shape[0] - 1, -1, -1):
        row = []
        for j in range(Z.shape[1]):
            v = lz[i, j]
            if np.isnan(v):
                row.append("·")   # infeasible cell (paper: missing surface region)
            else:
                row.append(_RAMP[min(int((v - lo) / span * (len(_RAMP) - 1e-9)), len(_RAMP) - 1)])
        lines.append(f"{ys[i]:>10g} |" + "".join(row))
    lines.append(" " * 11 + "+" + "-" * Z.shape[1])
    lines.append(" " * 12 + " ".join(f"{x:g}" for x in xs))
    return "\n".join(lines)


def grid_to_matrix(rows, x_name: str, y_name: str, cost_key=None):
    """Pivot CellResult rows into (xs, ys, Z) for rendering."""
    xs = sorted({r.params[x_name] for r in rows})
    ys = sorted({r.params[y_name] for r in rows})
    Z = np.full((len(ys), len(xs)), np.nan)
    for r in rows:
        i = ys.index(r.params[y_name])
        j = xs.index(r.params[x_name])
        Z[i, j] = r.cost() if cost_key is None else cost_key(r)
    return xs, ys, Z
