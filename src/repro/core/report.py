"""Markdown/CSV rendering of scoping results (EXPERIMENTS.md feedstock)."""
from __future__ import annotations

from typing import Optional


def markdown_table(headers: list, rows: list) -> str:
    out = ["| " + " | ".join(str(h) for h in headers) + " |",
           "|" + "|".join("---" for _ in headers) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


def fmt_si(v: Optional[float], unit: str = "") -> str:
    if v is None:
        return "—"
    for thr, suf in [(1e15, "P"), (1e12, "T"), (1e9, "G"), (1e6, "M"), (1e3, "k")]:
        if abs(v) >= thr:
            return f"{v / thr:.2f}{suf}{unit}"
    return f"{v:.3g}{unit}"


def fmt_time(s: Optional[float]) -> str:
    if s is None:
        return "—"
    if s >= 1.0:
        return f"{s:.2f}s"
    if s >= 1e-3:
        return f"{s * 1e3:.2f}ms"
    return f"{s * 1e6:.1f}us"


def csv_rows(result) -> str:
    names = result.param_names()
    lines = [",".join(names + ["cost_s"])]
    for r in result.rows:
        lines.append(",".join(str(r.params[n]) for n in names) + f",{r.cost():.6e}")
    return "\n".join(lines)
